"""Paper Fig. 8/9 (+13/14): asynchronous Poisson-arrival base→adapter
pipeline, varying arrival rate.

Reproduces the qualitative claims: higher arrival rates yield larger
aLoRA speedups (queue-time savings from the missing prefill backlog)
until cache capacity is reached, after which reuse decays (Fig. 9).
"""
from __future__ import annotations

from benchmarks.common import emit, make_engine, stage_row
from repro.serving import EngineConfig
from repro.serving import pipelines as P
from repro.serving.metrics import fmt_speedups, speedup_table

RATES = [1.0, 4.0, 16.0]
N_REQ = 6


def run():
    for rate in RATES:
        rows = {}
        for kind in ("lora", "alora"):
            for seed in (999, int(rate * 10)):    # warmup + measured
                eng = make_engine(kind)
                res = P.async_base_adapter(
                    eng, adapter_name="ad0", arrival_rate=rate,
                    num_requests=N_REQ, prompt_len=64, gen_len=24,
                    eval_len=8, seed=seed)
            m = res.stage_metrics(eng, "eval")
            rows[kind] = m
            emit(f"fig8/eval/{kind}/rate{rate}", m.means["e2e"] * 1e6,
                 stage_row(m))
            # wall-clock throughput over the stage's makespan (max done −
            # min arrival) — NOT tokens/Σe2e, which double-counts
            # overlapped request lifetimes under concurrency; the
            # per-request service rate is reported alongside
            emit(f"fig8/throughput/{kind}/rate{rate}",
                 m.throughput_tok_per_s,
                 f"tok/s over makespan; per-request rate="
                 f"{m.tok_per_req_s:.1f} tok/s")
        sp = speedup_table(rows["lora"], rows["alora"])
        emit(f"fig8/speedup/rate{rate}", 0.0, fmt_speedups(sp))

    # Fig. 9: cache-capacity cliff — a pool smaller than the in-flight
    # working set evicts base blocks before their adapter call arrives,
    # destroying reuse (and queue times blow up from block starvation)
    for blocks, label in ((512, "ample"), (24, "tight")):
        for seed in (99, 7):                      # warmup + measured
            eng = make_engine("alora",
                              ecfg=EngineConfig(num_blocks=blocks))
            res = P.async_base_adapter(eng, adapter_name="ad0",
                                       arrival_rate=32.0,
                                       num_requests=8, prompt_len=96,
                                       gen_len=24, eval_len=8, seed=seed)
        m = res.stage_metrics(eng, "eval")
        emit(f"fig9/capacity-{label}/blocks{blocks}",
             m.means["e2e"] * 1e6,
             f"hit={m.means['cache_hit_frac']:.2f} "
             f"evictions={eng.kv_mgr.evictions}")


if __name__ == "__main__":
    run()
