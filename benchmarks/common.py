"""Shared benchmark scaffolding.

Every benchmark mirrors one paper table/figure at reduced scale (CPU,
2-layer Granite-8B-family model — the paper's own base model family).
LoRA-vs-aLoRA comparisons run both variants over identical pipelines
with a jit warmup round first (different seed), so measured numbers are
compute, not compilation.
"""
from __future__ import annotations

import sys
from typing import Dict, List, Optional

import jax

from repro.configs import get_reduced
from repro.core.alora import (PAPER_ALORA_RANK, PAPER_LORA_RANK,
                              AdapterSpec, init_adapter_weights)
from repro.models import init_params
from repro.serving import Engine, EngineConfig

KEY = jax.random.key(0)
INV = (7, 8, 9)
ARCH = "granite-3.2-8b"

_cache: Dict = {}


def model(arch: str = ARCH):
    key = ("m", arch)
    if key not in _cache:
        cfg = get_reduced(arch)
        _cache[key] = (cfg, init_params(KEY, cfg))
    return _cache[key]


def make_engine(kind: str, n_adapters: int = 1,
                ecfg: Optional[EngineConfig] = None,
                arch: str = ARCH) -> Engine:
    cfg, params = model(arch)
    rank = PAPER_ALORA_RANK if kind == "alora" else PAPER_LORA_RANK
    ads = []
    for i in range(n_adapters):
        inv = tuple(x + i for x in INV) if kind == "alora" else None
        spec = AdapterSpec(f"ad{i}", rank=rank, invocation_tokens=inv)
        if (arch, "w", rank, i) not in _cache:
            _cache[(arch, "w", rank, i)] = init_adapter_weights(
                jax.random.key(100 + i), cfg, rank)
        ads.append((spec, _cache[(arch, "w", rank, i)]))
    return Engine(cfg, params, adapters=ads,
                  engine_cfg=ecfg or EngineConfig())


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def stage_row(metrics) -> str:
    """Render stage means; an EMPTY aggregate (a pipeline stage that saw
    no requests) yields NaNs from ``MetricsAggregate.row`` and renders
    every field as ``-`` instead of raising KeyError."""
    m = metrics.row(("queue", "prefill", "decode", "ttft",
                     "cache_hit_frac"))

    def us(v):
        return "-" if v != v else f"{v * 1e6:.0f}us"

    hit = "-" if m["cache_hit_frac"] != m["cache_hit_frac"] \
        else f"{m['cache_hit_frac']:.2f}"
    return (f"queue={us(m['queue'])} prefill={us(m['prefill'])} "
            f"decode={us(m['decode'])} ttft={us(m['ttft'])} hit={hit}")
