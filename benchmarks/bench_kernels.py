"""Kernel microbenchmarks.

Wall-clock on CPU times the jnp reference path (the engine's CPU
execution); the Pallas kernels are TPU artifacts validated in interpret
mode (correctness) — interpret-mode wall time is NOT a performance
number and is labelled as such.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels.ops import alora_qkv_op, paged_attention_op
from repro.kernels.ref import alora_qkv_ref, paged_attention_ref

KEY = jax.random.key(0)


def timeit(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    # aLoRA fused projection: T x d -> out with 3 adapters r=32
    T, d, out, n, r = 512, 256, 768, 4, 32
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (T, d))
    w = jax.random.normal(ks[1], (d, out)) * 0.1
    a = jax.random.normal(ks[2], (n, d, r)).at[0].set(0.0) * 0.1
    b = jax.random.normal(ks[3], (n, r, out)) * 0.1
    idx = jax.random.randint(ks[4], (T,), 0, n)

    ref_jit = jax.jit(alora_qkv_ref)
    us = timeit(ref_jit, x, w, a, b, idx)
    emit("kernels/alora_qkv/jnp-ref-cpu", us,
         f"T={T} d={d} out={out} n={n} r={r}")
    base_jit = jax.jit(lambda x, w: x @ w)
    us0 = timeit(base_jit, x, w)
    emit("kernels/alora_qkv/base-matmul-cpu", us0,
         f"adapter overhead={us/max(us0,1e-9):.2f}x")

    # paged attention decode
    B, H, KV, hd, NB, bs, nb = 8, 16, 4, 64, 128, 16, 16
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (NB, bs, KV, hd))
    vp = jax.random.normal(ks[2], (NB, bs, KV, hd))
    bt = jax.random.randint(ks[3], (B, nb), 0, NB)
    ln = jnp.full((B,), nb * bs)
    ref_pa = jax.jit(paged_attention_ref)
    us = timeit(ref_pa, q, kp, vp, bt, ln)
    emit("kernels/paged_attention/jnp-ref-cpu", us,
         f"B={B} H={H} KV={KV} hd={hd} S={nb*bs}")

    # interpret-mode correctness spot check (NOT a perf number)
    o1 = paged_attention_op(q, kp, vp, bt, ln, interpret=True)
    o2 = paged_attention_ref(q, kp, vp, bt, ln)
    err = float(jnp.abs(o1 - o2).max())
    emit("kernels/paged_attention/interpret-maxerr", 0.0, f"err={err:.1e}")

    # SSD chunk scan (mamba2/zamba2 hot spot)
    from repro.kernels.ops import ssd_chunk_ref, ssd_chunk_scan_op
    Bt, S, H, P, N = 2, 256, 4, 64, 16
    xs = jax.random.normal(ks[0], (Bt, S, H, P))
    Bm = jax.random.normal(ks[1], (Bt, S, H, N)) * 0.5
    Cm = jax.random.normal(ks[2], (Bt, S, H, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (Bt, S, H)))
    dA = -jnp.exp(jax.random.normal(ks[4], (Bt, S, H)) * 0.3) * dt
    ref_jit = jax.jit(ssd_chunk_ref)
    us = timeit(lambda *a: ref_jit(*a)[0], xs, Bm, Cm, dA, dt)
    emit("kernels/ssd_chunk/jnp-ref-cpu", us,
         f"B={Bt} S={S} H={H} P={P} N={N} (token recurrence)")
    y1, s1 = ssd_chunk_scan_op(xs, Bm, Cm, dA, dt, chunk=64,
                               interpret=True)
    y2, s2 = ssd_chunk_ref(xs, Bm, Cm, dA, dt)
    emit("kernels/ssd_chunk/interpret-maxerr", 0.0,
         f"err={float(jnp.abs(y1 - y2).max()):.1e}")


if __name__ == "__main__":
    run()
