"""Paper Fig. 11 (App. C): adapter→base pipeline — two-way reuse.

The adapter screens the prompt first; the base model then generates and
reuses the adapter's pre-activation prefill blocks.
"""
from __future__ import annotations

from benchmarks.common import emit, make_engine, stage_row
from repro.serving import pipelines as P
from repro.serving.metrics import fmt_speedups, speedup_table

PROMPT_LENS = [48, 96, 192]


def run():
    for plen in PROMPT_LENS:
        row = {}
        for kind in ("lora", "alora"):
            for seed in (9990 + plen, plen):      # warmup + measured
                eng = make_engine(kind)
                res = P.adapter_base(eng, adapter_name="ad0",
                                     prompt_len=plen, eval_len=16,
                                     gen_len=16, batch=2, seed=seed)
            m = res.stage_metrics(eng, "final")   # the base call
            row[kind] = m
            emit(f"fig11/base-after-adapter/{kind}/prompt{plen}",
                 m.means["e2e"] * 1e6, stage_row(m))
        sp = speedup_table(row["lora"], row["alora"])
        emit(f"fig11/speedup/prompt{plen}", 0.0, fmt_speedups(sp))


if __name__ == "__main__":
    run()
