"""Paper Fig. 15 (App. F): batch-size effect — larger concurrent batches
shift E2E toward decode time, motivating the paper's fixed-batch-size
methodology for the prompt-length sweeps."""
from __future__ import annotations

from benchmarks.common import emit, make_engine, stage_row
from repro.serving import EngineConfig
from repro.serving import pipelines as P

BATCHES = [1, 2, 4]


def run():
    for b in BATCHES:
        for seed in (999, b):                     # warmup + measured
            eng = make_engine("alora", ecfg=EngineConfig(max_running=8))
            res = P.base_adapter(eng, adapter_names=["ad0"],
                                 prompt_len=48, gen_len=24, eval_len=8,
                                 batch=b, seed=seed)
        m = res.stage_metrics(eng, "eval")
        emit(f"fig15/eval/batch{b}", m.means["e2e"] * 1e6, stage_row(m))


if __name__ == "__main__":
    run()
