"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun_all.jsonl (written by ``repro.launch.dryrun --all``)
and prints per-(arch × shape × mesh): the three roofline terms, the
dominant bottleneck, and the MODEL_FLOPS / HLO_FLOPs usefulness ratio.
"""
from __future__ import annotations

import json
import os
import sys

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun_all.jsonl")


def load(path=DEFAULT_PATH):
    recs = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # last wins
    return recs


def run(path=DEFAULT_PATH):
    recs = load(path)
    if not recs:
        print(f"roofline/no-data,0.0,run repro.launch.dryrun --all first "
              f"({path} missing)")
        return
    n_ok = sum(1 for r in recs.values() if r.get("ok"))
    print(f"roofline/summary,0.0,{n_ok}/{len(recs)} combos compiled OK")
    for (arch, shape, mesh), r in sorted(recs.items()):
        if not r.get("ok"):
            print(f"roofline/{arch}/{shape}/{mesh},0.0,"
                  f"FAILED: {r.get('error', '?')[:80]}")
            continue
        ro = r["roofline"]
        total = (ro["compute_s"] + ro["memory_s"] + ro["collective_s"])
        print(f"roofline/{arch}/{shape}/{mesh},"
              f"{max(ro['compute_s'], ro['memory_s'], ro['collective_s'])*1e6:.1f},"
              f"compute={ro['compute_s']:.3e} memory={ro['memory_s']:.3e} "
              f"collective={ro['collective_s']:.3e} "
              f"dominant={ro['dominant']} "
              f"useful={ro['useful_flops_ratio']:.2f} "
              f"temp_gib={r['memory'].get('temp_size_in_bytes', 0)/2**30:.1f}")


if __name__ == "__main__":
    run()
