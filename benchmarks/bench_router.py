"""Multi-replica router — cache-affinity placement vs round_robin.

The multi-turn agentic trace the paper's pipelines model (base → aLoRA
turns over a growing conversation prefix) is exactly the workload where
PLACEMENT decides the prefix-cache hit rate: turn k+1's prompt extends
turn k's full sequence, so its leading blocks are cached — but only on
the replica that served turn k.  ``serving.router.Router`` scores every
admission with the same aLoRA-aligned chained block hashes the cache
matches on (``Engine.cached_prefix_tokens``, non-acquiring), so later
turns follow their prefix; ``round_robin`` sprays turns across the
fleet and re-prefills prefixes some other replica already holds.

For each fleet size R (1, 2, 4; smoke: 1, 2) this runs the SAME
multi-session multi-adapter trace under both policies and reports, per
policy:

* fleet prefix-cache hit rate (summed hits / summed lookups over every
  replica — the headline number; affinity must beat round_robin for
  R > 1, asserted),
* fleet tokens/s through ``metrics_for`` → ``merge_aggregates`` (the
  union makespan: overlapped replica wall-clock counted ONCE — replica
  virtual clocks advance independently, so the fleet models R engines
  stepping concurrently),
* a per-replica row (requests served, hit rate, tok/s) + the fleet row.

R=1 is the degenerate sanity leg: both policies collapse to the single
engine and must match its hit rate exactly.  Appends one record per
(R, policy) to ``results/router.jsonl`` for ``benchmarks/report.py``.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import emit, make_engine, model, stage_row
from repro.serving import EngineConfig
from repro.serving.router import Router

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

# session counts are COPRIME to every fleet size: with sessions % R == 0
# a round_robin pointer that cycles straight through each round would
# map every session back to the replica that served its previous turn —
# accidental perfect affinity, and the policy contrast measures nothing.
# An odd count makes the blind mapping drift one replica per round, the
# honest baseline behavior (real traces have no such alignment either).
SESSIONS = 9
TURNS = 3
BASE_PROMPT = 40
TURN_TOKENS = 24
GEN_LEN = 8


def _mk_router(n: int, policy: str, arch: str) -> Router:
    # identical construction per replica (same cached params + adapter
    # weights, fresh pools) — registration order matches, so the uid
    # every block hash salts on agrees across the fleet
    ecfg = EngineConfig(max_running=4, max_batched_tokens=64,
                        adapter_slots=2)
    return Router([make_engine("alora", n_adapters=2, ecfg=ecfg,
                               arch=arch) for _ in range(n)],
                  policy=policy)


def _run_trace(router: Router, arch: str, seed: int,
               sessions: int, turns: int):
    """Drive the multi-turn trace; returns every router-global req id.

    Turn k+1 extends turn k's prompt + generated tokens (the agentic
    shape from ``serving/pipelines.py``), alternating base and aLoRA
    turns per session.  No ``session=`` pinning — placement quality
    must come from the locality SCORE alone, which is the policy
    contrast this benchmark exists to measure.
    """
    cfg, _ = model(arch)
    rng = np.random.RandomState(seed)
    hi = min(400, cfg.vocab_size)
    convo = [list(rng.randint(10, hi, BASE_PROMPT + 4 * (s % 3)))
             for s in range(sessions)]
    gids = []
    for t in range(turns):
        round_ids = []
        for s in range(sessions):
            adapter = f"ad{s % 2}" if t % 2 else None
            round_ids.append(router.submit(convo[s], GEN_LEN,
                                           adapter_name=adapter))
        router.run_until_idle()
        for s, gid in enumerate(round_ids):
            out = router.request(gid).output_tokens
            assert len(out) == GEN_LEN, (s, out)
            convo[s] = convo[s] + list(out) \
                + list(rng.randint(10, hi, TURN_TOKENS))
        gids.extend(round_ids)
    return gids


def run(arch: str = "granite-3.2-8b", smoke: bool = False):
    fleet_sizes = (1, 2) if smoke else (1, 2, 4)
    sessions = 5 if smoke else SESSIONS
    turns = 2 if smoke else TURNS
    hit_rates: dict = {}
    for n in fleet_sizes:
        for policy in ("affinity", "round_robin"):
            for seed in (999, 7):                 # warmup + measured
                router = _mk_router(n, policy, arch)
                gids = _run_trace(router, arch, seed, sessions, turns)
            fleet = router.metrics_for(gids)
            per = router.per_replica_metrics(gids)
            hit = router.kv_hit_rate()
            hit_rates[(n, policy)] = hit
            tag = f"R{n}/{policy}"
            emit(f"router/{arch}/{tag}/fleet_hit_rate", hit * 100,
                 f"hits/lookups across {n} replica(s); "
                 f"{len(gids)} requests")
            emit(f"router/{arch}/{tag}/fleet_tok_per_s",
                 fleet.throughput_tok_per_s,
                 f"union-makespan throughput; {stage_row(fleet)}")
            for idx, agg in sorted(per.items()):
                eng = router.replicas[idx]
                emit(f"router/{arch}/{tag}/replica{idx}",
                     agg.throughput_tok_per_s,
                     f"n={agg.n} hit={eng.kv_hit_rate():.2f} "
                     f"{stage_row(agg)}")
            os.makedirs(RESULTS, exist_ok=True)
            rec = dict(arch=arch, smoke=smoke, replicas=n, policy=policy,
                       fleet_hit_rate=hit,
                       fleet_tok_per_s=fleet.throughput_tok_per_s,
                       mean_ttft_s=fleet.means.get("ttft"),
                       n_requests=len(gids),
                       per_replica_n=[per[i].n if i in per else 0
                                      for i in range(n)],
                       reroutes=router.reroutes)
            with open(os.path.join(RESULTS, "router.jsonl"), "a") as f:
                f.write(json.dumps(rec) + "\n")
        # R=1: both policies ARE the single engine — identical trace,
        # identical placement, identical hit rate
        if n == 1:
            a, rr = hit_rates[(1, "affinity")], hit_rates[(1,
                                                           "round_robin")]
            assert abs(a - rr) < 1e-12, (a, rr)
        else:
            # the routing win this benchmark exists to show: locality
            # scoring must strictly beat blind placement on a multi-turn
            # trace (round_robin re-prefills prefixes another replica
            # already cached)
            a, rr = hit_rates[(n, "affinity")], hit_rates[(n,
                                                           "round_robin")]
            assert a > rr, \
                f"R={n}: affinity hit rate {a:.3f} <= round_robin {rr:.3f}"
            emit(f"router/{arch}/R{n}/affinity_vs_round_robin",
                 (a / rr if rr else float("inf")) * 100,
                 f"hit-rate ratio: affinity={a:.3f} round_robin={rr:.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3.2-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="R∈{1,2}, fewer sessions/turns for CI")
    args = ap.parse_args()
    run(arch=args.arch, smoke=args.smoke)
