"""Mixed-batch vs sequential execution — the unified-step architecture.

A step with K prefilling requests used to dispatch K prefill_chunk calls
plus one decode_batch call; the unified path packs every scheduled token
(decode singletons + prefill chunks) into ONE ragged jitted step.  This
section measures exactly that: device-calls/step and step latency for
the same workload under both execution modes, with a warmup round first
so measured numbers are compute, not compilation.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, make_engine
from repro.serving import EngineConfig

CONCURRENCY = 6
PROMPT_LEN = 72
GEN_LEN = 16


def _workload(eng, seed: int):
    rng = np.random.RandomState(seed)
    # staggered arrivals keep prefills and decodes overlapping, so most
    # steps genuinely mix both phases
    rids = []
    for i in range(CONCURRENCY):
        prompt = list(rng.randint(10, 400, PROMPT_LEN + 8 * (i % 3)))
        rids.append(eng.submit(prompt, GEN_LEN,
                               adapter_name="ad0" if i % 2 else None,
                               arrival_time=1e-9 * i))
    steps, mixed_steps, step_times = 0, 0, []
    while eng.pending or eng.waiting or eng.running:
        dt = eng.step()
        n_d, n_p = eng.last_step_tokens
        if n_d or n_p:
            steps += 1
            step_times.append(dt)
            if n_d and n_p:
                mixed_steps += 1
    return rids, steps, mixed_steps, step_times


def run():
    for mode in ("sequential", "mixed"):
        for seed in (999, 7):                     # warmup + measured
            eng = make_engine(
                "alora",
                ecfg=EngineConfig(max_running=8, max_batched_tokens=128,
                                  execution_mode=mode))
            rids, steps, mixed_steps, times = _workload(eng, seed)
        calls = eng.runner.num_device_calls
        out_toks = sum(len(eng.request(r).output_tokens) for r in rids)
        assert out_toks == sum(GEN_LEN for _ in rids)
        emit(f"mixed_batch/{mode}/step_latency",
             float(np.mean(times)) * 1e6,
             f"p50={np.median(times)*1e6:.0f}us "
             f"p99={np.percentile(times, 99)*1e6:.0f}us")
        emit(f"mixed_batch/{mode}/device_calls_per_step",
             calls / max(steps, 1),
             f"calls={calls} steps={steps} both_phase_steps={mixed_steps} "
             f"counts={eng.runner.call_counts}")


if __name__ == "__main__":
    run()
