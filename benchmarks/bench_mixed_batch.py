"""Mixed-batch vs sequential execution — the unified-step architecture.

A step with K prefilling requests used to dispatch K prefill_chunk calls
plus one decode_batch call; the unified path packs every scheduled token
(decode singletons + prefill chunks) into ONE ragged jitted step — for
EVERY architecture family (attention, SSM/hybrid via the ragged SSD
scan, encoder-decoder).  This section measures exactly that:
device-calls/step and step latency for the same workload under both
execution modes, with a warmup round first so measured numbers are
compute, not compilation.

Host-side batch assembly goes through the runner's persistent
capacity-doubling buffers (``HostBufferPool``); the
``assembly_us_per_step`` metric isolates that host cost.  Set
``REPRO_HOST_BUF_REUSE=0`` to re-measure with per-step reallocation (the
pre-pool behavior) for an A/B of the ROADMAP "pinned buffer" item.

``--mesh data=D,model=N`` adds a TP-sharded leg: the SAME mixed workload
over an (D, N) host mesh (``EngineConfig.mesh``), reporting per-step
latency and assembly time against the single-device mixed baseline and
asserting the sharded invariants (token identity, 1.0 device-calls/step,
zero post-warmup recompiles).  Needs
``XLA_FLAGS=--xla_force_host_platform_device_count=D*N``; on CPU the
sharded leg is a correctness/invariant gauge, not a speed gauge — host
meshes time collective overhead, real TP speedups need real chips.
Appends one record per run to ``results/sharded_step.jsonl`` for
``benchmarks/report.py``.

``--data-shard`` (with ``--mesh data=D,...``, D>1) turns on data-
parallel token sharding for the sharded leg
(``EngineConfig.data_shard_tokens``): the packed token axis of the
mixed step splits over the D data devices instead of every device
redundantly computing the full batch.  Without the flag the sharded leg
pins ``data_shard_tokens=False`` (the replicate-everything TP layout) —
the two runs are the A/B for the token-sharding change, and both assert
the same invariants (token identity with the single-device run, 1.0
device-calls/step, zero post-warmup recompiles).

``--async`` adds an async-submission leg (``EngineConfig.
async_submission``, the schedule → submit → retire pipeline): the same
workload with one-step-lookahead submission, asserting the async
invariants — token identity with the synchronous mixed oracle, 1.0
device-calls/step, every non-first work step assembled while the
previous step was still in flight (host work hidden under device
compute), and a device→host payload of SAMPLED int32 IDS ONLY (the
``(R, vocab)`` logits never cross on the decode path; checked against
the runner's ``d2h_fetches`` log).

``--trace-check`` adds a tracing-overhead leg: the SAME mixed workload
with the tracer force-enabled vs force-disabled
(``EngineConfig.trace``), asserting the enabled run's mean step latency
stays within the 2% overhead budget ``docs/observability.md`` promises
(best-of-3 attempts — single CPU runs are noisy).  The enabled run's
rings are exported to ``results/trace_mixed.perfetto.json`` (load at
https://ui.perfetto.dev) and the overhead record appends to
``results/trace_overhead.jsonl``.

Every measured mode also appends one observability record (the
runner's ``log_d2h`` ring summarized per tag, plus the cache-reuse
ledger rolled up per adapter) to ``results/obs.jsonl`` — the inputs for
``benchmarks/report.py``'s D2H-payload and adapter-reuse tables.

``--arch`` selects any registered architecture (default: the paper's
granite base model); ``--smoke`` shrinks the workload for CI.  CI runs
``--arch mamba2-2.7b --smoke`` as the tiny-SSM smoke leg and checks the
1.0-device-calls/step invariant this module asserts for mixed mode; the
``sharded`` CI leg runs ``--smoke --mesh data=2,model=4``; the
``async`` leg runs ``--smoke --async``; the ``obs`` leg runs
``--smoke --trace-check``.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import emit, make_engine
from repro.obs import d2h_summary, reuse_by_adapter, write_perfetto
from repro.serving import EngineConfig
from repro.serving import runner as runner_mod

CONCURRENCY = 6
PROMPT_LEN = 72
GEN_LEN = 16
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def parse_mesh(s: str) -> dict:
    """'model=4' | 'data=2,model=4' -> make_host_mesh kwargs."""
    kw = {"data": 1, "model": 1}
    for part in s.split(","):
        k, v = part.split("=")
        if k.strip() not in kw:
            raise ValueError(f"unknown mesh axis {k!r} (data/model)")
        kw[k.strip()] = int(v)
    return kw


def _workload(eng, seed: int, concurrency: int, prompt_len: int,
              gen_len: int):
    cfg = eng.cfg
    rng = np.random.RandomState(seed)
    # staggered arrivals keep prefills and decodes overlapping, so most
    # steps genuinely mix both phases
    rids = []
    for i in range(concurrency):
        prompt = list(rng.randint(10, min(400, cfg.vocab_size),
                                  prompt_len + 8 * (i % 3)))
        kw = {}
        if cfg.is_encoder_decoder:
            kw = dict(frame_embeds=rng.randn(
                cfg.encoder_seq_len, cfg.d_model).astype(np.float32),
                salt=(seed, i))
        rids.append(eng.submit(prompt, gen_len,
                               adapter_name="ad0" if i % 2 else None,
                               arrival_time=1e-9 * i, **kw))
    steps, mixed_steps, step_times = 0, 0, []
    while eng.pending or eng.waiting or eng.running:
        dt = eng.step()
        n_d, n_p = eng.last_step_tokens
        if n_d or n_p:
            steps += 1
            step_times.append(dt)
            if n_d and n_p:
                mixed_steps += 1
    return rids, steps, mixed_steps, step_times


TRACE_OVERHEAD_BUDGET = 0.02      # docs/observability.md's promise
TRACE_CHECK_ATTEMPTS = 3          # best-of-N: single CPU runs are noisy


def trace_overhead_check(arch: str, smoke: bool, concurrency: int,
                         prompt_len: int, gen_len: int) -> None:
    """The tracing-overhead leg: identical mixed workloads with the
    tracer force-enabled vs force-disabled; the enabled run must stay
    within the 2% mean-step-latency budget (best of N attempts)."""
    def measure(flag: bool):
        eng = None
        for seed in (999, 7):                     # warmup + measured
            eng = make_engine("alora", arch=arch, ecfg=EngineConfig(
                max_running=8, max_batched_tokens=128, trace=flag))
            _, _, _, times = _workload(eng, seed, concurrency,
                                       prompt_len, gen_len)
        return float(np.mean(times)) * 1e6, eng

    best, on_us, off_us, traced_eng = None, 0.0, 0.0, None
    for attempt in range(TRACE_CHECK_ATTEMPTS):
        off_us, _ = measure(False)
        on_us, traced_eng = measure(True)
        overhead = (on_us - off_us) / off_us
        best = overhead if best is None else min(best, overhead)
        if best < TRACE_OVERHEAD_BUDGET:
            break
    assert best is not None and best < TRACE_OVERHEAD_BUDGET, \
        f"tracing overhead {best:.1%} exceeds the " \
        f"{TRACE_OVERHEAD_BUDGET:.0%} budget"
    emit(f"mixed_batch/{arch}/trace_overhead", best * 100,
         f"traced={on_us:.0f}us untraced={off_us:.0f}us "
         f"(% mean step latency, best of {attempt + 1})")
    os.makedirs(RESULTS, exist_ok=True)
    write_perfetto(os.path.join(RESULTS, "trace_mixed.perfetto.json"),
                   [traced_eng.tracer])
    with open(os.path.join(RESULTS, "trace_overhead.jsonl"), "a") as f:
        f.write(json.dumps(dict(
            arch=arch, smoke=smoke, traced_us=on_us, untraced_us=off_us,
            overhead_pct=best * 100, attempts=attempt + 1,
            events=len(traced_eng.tracer.events))) + "\n")


def run(arch: str = "granite-3.2-8b", smoke: bool = False,
        mesh: dict | None = None, async_leg: bool = False,
        data_shard: bool = False, trace_check: bool = False):
    if data_shard and (mesh is None or mesh.get("data", 1) < 2):
        raise SystemExit("--data-shard needs --mesh data=D,... with D>1")
    concurrency = 3 if smoke else CONCURRENCY
    prompt_len = 24 if smoke else PROMPT_LEN
    gen_len = 8 if smoke else GEN_LEN
    # "mixed" is pinned to the SYNCHRONOUS oracle (async_submission off)
    # so the async and sharded legs have a baseline to be token-checked
    # against; "mixed_async" (--async) runs the one-step-lookahead
    # pipeline; "mixed_sharded" (--mesh) keeps the async default ON —
    # the async × TP-sharded combination.
    modes = ["sequential", "mixed"] \
        + (["mixed_async"] if async_leg else []) \
        + (["mixed_sharded"] if mesh else [])
    baseline_us = None            # single-device mixed mean step latency
    mixed_tokens = None
    for mode in modes:
        ecfg_kw = dict(max_running=8, max_batched_tokens=128)
        if mode == "mixed_sharded":
            from repro.launch.mesh import make_host_mesh
            ecfg_kw["mesh"] = make_host_mesh(**mesh)
            ecfg_kw["data_shard_tokens"] = data_shard
        elif mode == "mixed_async":
            pass                            # defaults: mixed + async on
        else:
            ecfg_kw["execution_mode"] = mode
            ecfg_kw["async_submission"] = False
        for seed in (999, 7):                     # warmup + measured
            eng = make_engine("alora", arch=arch,
                              ecfg=EngineConfig(**ecfg_kw))
            if seed == 7 and mode == "mixed_sharded":
                compiles_before = runner_mod.jit_cache_size()
            rids, steps, mixed_steps, times = _workload(
                eng, seed, concurrency, prompt_len, gen_len)
        calls = eng.runner.num_device_calls
        out = [eng.request(r).output_tokens for r in rids]
        out_toks = sum(len(t) for t in out)
        assert out_toks == sum(gen_len for _ in rids)
        if mode == "mixed":
            mixed_tokens = out
            baseline_us = float(np.mean(times)) * 1e6
        # keep emit()'s CSV name comma-free: 2x4 = (data=2, model=4);
        # "+ds" marks the token-sharded (data-parallel) flavor
        tag = mode if mesh is None or mode != "mixed_sharded" else \
            f"mixed@{mesh['data']}x{mesh['model']}" \
            + ("+ds" if data_shard else "")
        if mode != "sequential" and not eng.cfg.is_encoder_decoder:
            # the unified-step invariant: one jitted call per work step
            assert calls == steps, (calls, steps)
        emit(f"mixed_batch/{arch}/{tag}/step_latency",
             float(np.mean(times)) * 1e6,
             f"p50={np.median(times)*1e6:.0f}us "
             f"p99={np.percentile(times, 99)*1e6:.0f}us")
        emit(f"mixed_batch/{arch}/{tag}/device_calls_per_step",
             calls / max(steps, 1),
             f"calls={calls} steps={steps} both_phase_steps={mixed_steps} "
             f"counts={eng.runner.call_counts}")
        # observability record: the runner's D2H ring per tag + the
        # cache-reuse ledger per adapter — report.py's obs tables
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, "obs.jsonl"), "a") as f:
            f.write(json.dumps(dict(
                arch=arch, smoke=smoke, mode=tag, steps=steps,
                d2h=d2h_summary(eng.runner.d2h_fetches),
                reuse=reuse_by_adapter([eng.tracer]))) + "\n")
        if mode != "sequential":
            # engine-side packing + runner-side bucket padding/stacking —
            # everything the HostBufferPool covers
            t_asm = eng.t_assembly + eng.runner.t_assembly
            emit(f"mixed_batch/{arch}/{tag}/assembly_us_per_step",
                 t_asm / max(steps, 1) * 1e6,
                 f"host batch-pack time (persistent buffers; set "
                 f"REPRO_HOST_BUF_REUSE=0 for the realloc baseline)")
        if mode == "mixed_async":
            # async invariants: token identity with the synchronous
            # mixed oracle; every work step after the first assembled
            # while the previous step was still in flight; the D2H
            # payload is sampled int32 ids only — never (R, vocab)
            # logits (no full-logits transfer on the decode path)
            assert out == mixed_tokens, \
                "async submission diverged from the sync mixed oracle"
            overlap = eng.async_overlap_steps
            assert overlap >= steps - 2, (overlap, steps)
            fetches = [(e, d) for e, d, tag in eng.runner.d2h_fetches
                       if tag == "step"]
            assert fetches and all(d == "int32" for _, d in fetches), \
                [d for _, d in fetches[:4]]
            max_elems = max(e for e, _ in fetches)
            assert max_elems < eng.cfg.vocab_size, \
                f"per-step D2H of {max_elems} elems looks like logits"
            async_us = float(np.mean(times)) * 1e6
            emit(f"mixed_batch/{arch}/{tag}/vs_sync_submission",
                 async_us / baseline_us,
                 f"async={async_us:.0f}us sync={baseline_us:.0f}us "
                 f"overlapped={overlap}/{steps} steps "
                 f"d2h_max={max_elems} int32 elems/step (ids, not "
                 f"logits)")
        if mode == "mixed_sharded":
            # sharded invariants: token identity with the single-device
            # mixed run, exactly one jitted call per work step (asserted
            # above), zero post-warmup recompiles
            assert out == mixed_tokens, \
                "sharded mixed step diverged from single-device tokens"
            recompiles = runner_mod.jit_cache_size() - compiles_before
            assert recompiles == 0, \
                f"{recompiles} post-warmup recompiles under sharding"
            sharded_us = float(np.mean(times)) * 1e6
            emit(f"mixed_batch/{arch}/{tag}/vs_single_device",
                 sharded_us / baseline_us,
                 f"sharded={sharded_us:.0f}us single={baseline_us:.0f}us "
                 f"(host-mesh collective overhead; TP wins need real "
                 f"chips)")
            os.makedirs(RESULTS, exist_ok=True)
            rec = dict(arch=arch, smoke=smoke,
                       mesh=f"{mesh['data']}x{mesh['model']}",
                       data_shard=data_shard,
                       step_latency_us=sharded_us,
                       baseline_us=baseline_us,
                       assembly_us_per_step=t_asm / max(steps, 1) * 1e6,
                       device_calls_per_step=calls / max(steps, 1),
                       recompiles_after_warmup=recompiles,
                       steps=steps)
            with open(os.path.join(RESULTS, "sharded_step.jsonl"),
                      "a") as f:
                f.write(json.dumps(rec) + "\n")
    if trace_check:
        trace_overhead_check(arch, smoke, concurrency, prompt_len,
                             gen_len)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3.2-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI smoke runs")
    ap.add_argument("--async", dest="async_leg", action="store_true",
                    help="add an async-submission leg (one-step "
                         "lookahead) checked token-for-token against "
                         "the synchronous mixed oracle, asserting the "
                         "sampled-ids-only D2H payload")
    ap.add_argument("--mesh", default=None,
                    help="add a TP-sharded mixed leg over a host mesh, "
                         "e.g. 'model=4' or 'data=2,model=4' (needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N)")
    ap.add_argument("--data-shard", dest="data_shard",
                    action="store_true",
                    help="shard the packed token axis over the mesh "
                         "data axis in the sharded leg (needs --mesh "
                         "data=D,... with D>1); off = replicate-"
                         "everything TP baseline")
    ap.add_argument("--trace-check", dest="trace_check",
                    action="store_true",
                    help="add a tracing-overhead leg: tracer on vs off "
                         "on the same mixed workload, asserting the <2% "
                         "mean-step-latency budget and exporting the "
                         "traced run's Perfetto timeline")
    args = ap.parse_args()
    run(arch=args.arch, smoke=args.smoke,
        mesh=parse_mesh(args.mesh) if args.mesh else None,
        async_leg=args.async_leg, data_shard=args.data_shard,
        trace_check=args.trace_check)
