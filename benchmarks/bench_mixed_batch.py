"""Mixed-batch vs sequential execution — the unified-step architecture.

A step with K prefilling requests used to dispatch K prefill_chunk calls
plus one decode_batch call; the unified path packs every scheduled token
(decode singletons + prefill chunks) into ONE ragged jitted step — for
EVERY architecture family (attention, SSM/hybrid via the ragged SSD
scan, encoder-decoder).  This section measures exactly that:
device-calls/step and step latency for the same workload under both
execution modes, with a warmup round first so measured numbers are
compute, not compilation.

Host-side batch assembly goes through the runner's persistent
capacity-doubling buffers (``HostBufferPool``); the
``assembly_us_per_step`` metric isolates that host cost.  Set
``REPRO_HOST_BUF_REUSE=0`` to re-measure with per-step reallocation (the
pre-pool behavior) for an A/B of the ROADMAP "pinned buffer" item.

``--arch`` selects any registered architecture (default: the paper's
granite base model); ``--smoke`` shrinks the workload for CI.  CI runs
``--arch mamba2-2.7b --smoke`` as the tiny-SSM smoke leg and checks the
1.0-device-calls/step invariant this module asserts for mixed mode.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, make_engine
from repro.serving import EngineConfig

CONCURRENCY = 6
PROMPT_LEN = 72
GEN_LEN = 16


def _workload(eng, seed: int, concurrency: int, prompt_len: int,
              gen_len: int):
    cfg = eng.cfg
    rng = np.random.RandomState(seed)
    # staggered arrivals keep prefills and decodes overlapping, so most
    # steps genuinely mix both phases
    rids = []
    for i in range(concurrency):
        prompt = list(rng.randint(10, min(400, cfg.vocab_size),
                                  prompt_len + 8 * (i % 3)))
        kw = {}
        if cfg.is_encoder_decoder:
            kw = dict(frame_embeds=rng.randn(
                cfg.encoder_seq_len, cfg.d_model).astype(np.float32),
                salt=(seed, i))
        rids.append(eng.submit(prompt, gen_len,
                               adapter_name="ad0" if i % 2 else None,
                               arrival_time=1e-9 * i, **kw))
    steps, mixed_steps, step_times = 0, 0, []
    while eng.pending or eng.waiting or eng.running:
        dt = eng.step()
        n_d, n_p = eng.last_step_tokens
        if n_d or n_p:
            steps += 1
            step_times.append(dt)
            if n_d and n_p:
                mixed_steps += 1
    return rids, steps, mixed_steps, step_times


def run(arch: str = "granite-3.2-8b", smoke: bool = False):
    concurrency = 3 if smoke else CONCURRENCY
    prompt_len = 24 if smoke else PROMPT_LEN
    gen_len = 8 if smoke else GEN_LEN
    for mode in ("sequential", "mixed"):
        for seed in (999, 7):                     # warmup + measured
            eng = make_engine(
                "alora", arch=arch,
                ecfg=EngineConfig(max_running=8, max_batched_tokens=128,
                                  execution_mode=mode))
            rids, steps, mixed_steps, times = _workload(
                eng, seed, concurrency, prompt_len, gen_len)
        calls = eng.runner.num_device_calls
        out_toks = sum(len(eng.request(r).output_tokens) for r in rids)
        assert out_toks == sum(gen_len for _ in rids)
        if mode == "mixed" and not eng.cfg.is_encoder_decoder:
            # the unified-step invariant: one jitted call per work step
            assert calls == steps, (calls, steps)
        emit(f"mixed_batch/{arch}/{mode}/step_latency",
             float(np.mean(times)) * 1e6,
             f"p50={np.median(times)*1e6:.0f}us "
             f"p99={np.percentile(times, 99)*1e6:.0f}us")
        emit(f"mixed_batch/{arch}/{mode}/device_calls_per_step",
             calls / max(steps, 1),
             f"calls={calls} steps={steps} both_phase_steps={mixed_steps} "
             f"counts={eng.runner.call_counts}")
        if mode == "mixed":
            # engine-side packing + runner-side bucket padding/stacking —
            # everything the HostBufferPool covers
            t_asm = eng.t_assembly + eng.runner.t_assembly
            emit(f"mixed_batch/{arch}/{mode}/assembly_us_per_step",
                 t_asm / max(steps, 1) * 1e6,
                 f"host batch-pack time (persistent buffers; set "
                 f"REPRO_HOST_BUF_REUSE=0 for the realloc baseline)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3.2-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI smoke runs")
    args = ap.parse_args()
    run(arch=args.arch, smoke=args.smoke)
