"""Benchmark harness entrypoint — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig6 fig8  # subset
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_adapter_base, bench_async,
                            bench_batch_size, bench_generation_length,
                            bench_kernels, bench_mixed_batch,
                            bench_multi_adapter, bench_prompt_length,
                            roofline)
    sections = {
        "mixed_batch": bench_mixed_batch.run,  # unified-step vs v0 path
        "fig6": bench_prompt_length.run,       # prompt-length sweep
        "fig11": bench_adapter_base.run,       # adapter->base
        "fig10": bench_generation_length.run,  # generation-length sweep
        "fig8": bench_async.run,               # async Poisson (+fig9)
        "sec441": bench_multi_adapter.run,     # 5 parallel adapters
        "fig15": bench_batch_size.run,         # batch-size effect
        "kernels": bench_kernels.run,
        "roofline": roofline.run,
    }
    chosen = sys.argv[1:] or list(sections)
    print("name,us_per_call,derived")
    for name in chosen:
        t0 = time.time()
        sections[name]()
        print(f"section/{name}/wall_s,{(time.time()-t0)*1e6:.0f},")


if __name__ == "__main__":
    main()
