"""Paper Fig. 6 + Fig. 12: base→adapter pipeline, varying prompt length.

Evaluation-step stage latencies (queue/prefill/decode, TTFT, E2E) for
vanilla LoRA vs aLoRA, plus the prefix-cache hit rate (§4.2 reports 84%
at prompt 1024; hit rate here is tokens-reused / prompt-len of the
adapter call).
"""
from __future__ import annotations

from benchmarks.common import emit, make_engine, stage_row
from repro.serving import pipelines as P
from repro.serving.metrics import fmt_speedups, speedup_table

PROMPT_LENS = [48, 96, 192, 384]
GEN_LEN = 32
EVAL_LEN = 8


def run(out_rows=None):
    results = {}
    for plen in PROMPT_LENS:
        for kind in ("lora", "alora"):
            # two passes: the first compiles every jit bucket this
            # config touches, the second measures with a fresh engine
            # (cold caches, warm code)
            for seed in (9990 + plen, plen):
                eng = make_engine(kind)
                res = P.base_adapter(eng, adapter_names=["ad0"],
                                     prompt_len=plen, gen_len=GEN_LEN,
                                     eval_len=EVAL_LEN, batch=2,
                                     seed=seed)
            m = res.stage_metrics(eng, "eval")
            results[(plen, kind)] = m
            emit(f"fig6/eval/{kind}/prompt{plen}",
                 m.means["e2e"] * 1e6, stage_row(m))
        sp = speedup_table(results[(plen, "lora")],
                           results[(plen, "alora")])
        emit(f"fig6/speedup/prompt{plen}", 0.0, fmt_speedups(sp))
    return results


if __name__ == "__main__":
    run()
