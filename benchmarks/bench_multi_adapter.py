"""Paper §4.4.1: five adapters invoked in parallel on the same (x+y)
context + consolidated final base call.

``--churn`` instead exercises the dynamic adapter-lifecycle subsystem:
more adapters REGISTERED than device slots, requests cycling through
them so admission constantly pins/evicts/prefetches slots.  Asserts the
two churn invariants (CI runs this at tiny scale via ``--churn
--smoke``):

* 1.0 device-calls/step — adapter installs/prefetches happen off the
  step path, so the mixed step stays one jitted call per iteration;
* zero recompiles after warmup — the jitted step functions' jit caches
  (the engine's cache-miss counter) must not grow while adapters cycle
  through slots, and the output must be token-identical to an
  all-resident sequential oracle.

Adapter-lifecycle counters (prefetch issued/hit, evictions, occupancy,
stalled installs) are emitted per run and appended to
``results/adapter_pool.jsonl`` for ``benchmarks/report.py``.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import emit, make_engine, stage_row
from repro.serving import EngineConfig
from repro.serving import runner as runner_mod
from repro.serving import pipelines as P
from repro.serving.metrics import fmt_speedups, speedup_table

N_ADAPTERS = 5
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def run():
    names = [f"ad{i}" for i in range(N_ADAPTERS)]
    rows = {}
    for kind in ("lora", "alora"):
        for seed in (999, 4):                     # warmup + measured
            eng = make_engine(kind, n_adapters=N_ADAPTERS)
            res = P.base_adapter(eng, adapter_names=names, prompt_len=64,
                                 gen_len=32, eval_len=8,
                                 feed_back_to_base=True, seed=seed)
        m_eval = res.stage_metrics(eng, "eval")
        m_final = res.stage_metrics(eng, "final")
        rows[kind] = (m_eval, m_final)
        emit(f"sec441/eval-5adapters/{kind}", m_eval.means["e2e"] * 1e6,
             stage_row(m_eval))
        emit(f"sec441/final-base/{kind}", m_final.means["e2e"] * 1e6,
             f"ttft={m_final.means['ttft']*1e6:.0f}us "
             f"hit={m_final.means['cache_hit_frac']:.2f}")
    sp = speedup_table(rows["lora"][0], rows["alora"][0])
    emit("sec441/speedup-eval", 0.0, fmt_speedups(sp))


# ---------------------------------------------------------------------------
# adapter-churn leg (dynamic adapter lifecycle)
# ---------------------------------------------------------------------------
def _churn_workload(eng, *, n_adapters: int, reps: int, prompt_len: int,
                    gen_len: int, seed: int):
    rng = np.random.RandomState(seed)
    rids = []
    k = 0
    for rep in range(reps):
        for i in range(n_adapters):
            inv = list(eng.adapters[f"ad{i}"].spec.invocation_tokens)
            prompt = list(rng.randint(10, 400, prompt_len)) + inv
            rids.append(eng.submit(prompt, gen_len,
                                   adapter_name=f"ad{i}",
                                   arrival_time=1e-9 * k))
            k += 1
    steps, times, occ = 0, [], []
    while eng.pending or eng.waiting or eng.running:
        dt = eng.step()
        n_d, n_p = eng.last_step_tokens
        if n_d or n_p:
            steps += 1
            times.append(dt)
            occ.append(eng.adapter_pool.occupancy)
    return rids, steps, times, occ


def run_churn(arch: str, smoke: bool = False):
    n_adapters = 4 if smoke else 8
    slots = 2 if smoke else 3
    prompt_len = 24 if smoke else 64
    gen_len = 6 if smoke else 16
    reps = 2 if smoke else 3
    kw = dict(n_adapters=n_adapters, reps=reps, prompt_len=prompt_len,
              gen_len=gen_len)

    # all-resident sequential oracle for token-identity
    eng_o = make_engine("alora", n_adapters=n_adapters, arch=arch,
                        ecfg=EngineConfig(max_running=4,
                                          execution_mode="sequential"))
    rids_o, *_ = _churn_workload(eng_o, seed=7, **kw)
    oracle = [eng_o.request(r).output_tokens for r in rids_o]

    def mk():
        return make_engine("alora", n_adapters=n_adapters, arch=arch,
                           ecfg=EngineConfig(max_running=4,
                                             adapter_slots=slots))

    eng = mk()
    _churn_workload(eng, seed=999, **kw)          # warmup (jit traces)
    compiles_before = runner_mod.jit_cache_size()
    eng = mk()                                    # fresh pool, warm jit
    calls_before = eng.runner.num_device_calls
    rids, steps, times, occ = _churn_workload(eng, seed=7, **kw)
    calls = eng.runner.num_device_calls - calls_before

    out = [eng.request(r).output_tokens for r in rids]
    assert out == oracle, "churn output diverged from all-resident oracle"
    assert calls == steps, (calls, steps)         # 1.0 device-calls/step
    recompiles = runner_mod.jit_cache_size() - compiles_before
    assert recompiles == 0, f"{recompiles} post-warmup recompiles"
    st = eng.adapter_pool_stats()
    assert st.evictions > 0, "churn never evicted — slots not scarce?"

    emit(f"adapter_churn/{arch}/step_latency",
         float(np.mean(times)) * 1e6,
         f"p50={np.median(times)*1e6:.0f}us steps={steps}")
    emit(f"adapter_churn/{arch}/device_calls_per_step", calls / steps,
         f"calls={calls} steps={steps} recompiles_after_warmup="
         f"{recompiles}")
    emit(f"adapter_churn/{arch}/adapter_pool",
         float(np.mean(occ)),
         f"slots={st.num_slots} registered={st.num_registered} "
         f"prefetch={st.prefetch_issued}/{st.prefetch_hits}hit "
         f"installs={st.installs} evictions={st.evictions} "
         f"stalled={st.stalled_installs} queued_on_slots="
         f"{st.acquire_fails}")

    os.makedirs(RESULTS, exist_ok=True)
    rec = dict(arch=arch, smoke=smoke, n_adapters=n_adapters,
               steps=steps, device_calls_per_step=calls / steps,
               recompiles_after_warmup=recompiles,
               occupancy_mean=float(np.mean(occ)), **st.row())
    with open(os.path.join(RESULTS, "adapter_pool.jsonl"), "a") as f:
        f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3.2-8b")
    ap.add_argument("--churn", action="store_true",
                    help="adapter-lifecycle churn leg (N registered > "
                         "device slots)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI smoke runs")
    args = ap.parse_args()
    if args.churn:
        run_churn(args.arch, smoke=args.smoke)
    else:
        run()
