"""Paper §4.4.1: five adapters invoked in parallel on the same (x+y)
context + consolidated final base call."""
from __future__ import annotations

from benchmarks.common import emit, make_engine, stage_row
from repro.serving import pipelines as P
from repro.serving.metrics import speedup_table

N_ADAPTERS = 5


def run():
    names = [f"ad{i}" for i in range(N_ADAPTERS)]
    rows = {}
    for kind in ("lora", "alora"):
        for seed in (999, 4):                     # warmup + measured
            eng = make_engine(kind, n_adapters=N_ADAPTERS)
            res = P.base_adapter(eng, adapter_names=names, prompt_len=64,
                                 gen_len=32, eval_len=8,
                                 feed_back_to_base=True, seed=seed)
        m_eval = res.stage_metrics(eng, "eval")
        m_final = res.stage_metrics(eng, "final")
        rows[kind] = (m_eval, m_final)
        emit(f"sec441/eval-5adapters/{kind}", m_eval.means["e2e"] * 1e6,
             stage_row(m_eval))
        emit(f"sec441/final-base/{kind}", m_final.means["e2e"] * 1e6,
             f"ttft={m_final.means['ttft']*1e6:.0f}us "
             f"hit={m_final.means['cache_hit_frac']:.2f}")
    sp = speedup_table(rows["lora"][0], rows["alora"][0])
    emit("sec441/speedup-eval", 0.0,
         " ".join(f"{k}={v:.2f}x" for k, v in sp.items()))


if __name__ == "__main__":
    run()
