"""Paper §4.4.1: five adapters invoked in parallel on the same (x+y)
context + consolidated final base call.

``--churn`` instead exercises the dynamic adapter-lifecycle subsystem:
more adapters REGISTERED than device slots, requests cycling through
them so admission constantly pins/evicts/prefetches slots.  Asserts the
two churn invariants (CI runs this at tiny scale via ``--churn
--smoke``):

* 1.0 device-calls/step — adapter installs/prefetches happen off the
  step path, so the mixed step stays one jitted call per iteration;
* zero recompiles after warmup — the jitted step functions' jit caches
  (the engine's cache-miss counter) must not grow while adapters cycle
  through slots, and the output must be token-identical to an
  all-resident sequential oracle.

Adapter-lifecycle counters (prefetch issued/hit, evictions, occupancy,
stalled installs) are emitted per run and appended to
``results/adapter_pool.jsonl`` for ``benchmarks/report.py``.

``--zipf`` is the thousand-adapter-regime scheduling leg
(docs/scheduling.md): a deep queue of requests whose adapters follow a
Zipf popularity law over far more registrations than device slots, run
twice on the SAME trace — once under the strict-FCFS admission oracle
(``admission_policy="fcfs"``) and once under the adapter-affinity
scheduler (the default).  Asserts the affinity scheduler's measured win
(strictly fewer acquire-fails and stalled installs, strictly lower mean
queue latency), token identity between the two policies on an
uncontended-slot trace, and the standing churn invariants (1.0
device-calls/step, zero post-warmup recompiles) under reordering.
Appends per-policy rows to ``results/adapter_sched.jsonl``.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import emit, make_engine, stage_row
from repro.serving import EngineConfig
from repro.serving import runner as runner_mod
from repro.serving import pipelines as P
from repro.serving.metrics import fmt_speedups, speedup_table

N_ADAPTERS = 5
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def run():
    names = [f"ad{i}" for i in range(N_ADAPTERS)]
    rows = {}
    for kind in ("lora", "alora"):
        for seed in (999, 4):                     # warmup + measured
            eng = make_engine(kind, n_adapters=N_ADAPTERS)
            res = P.base_adapter(eng, adapter_names=names, prompt_len=64,
                                 gen_len=32, eval_len=8,
                                 feed_back_to_base=True, seed=seed)
        m_eval = res.stage_metrics(eng, "eval")
        m_final = res.stage_metrics(eng, "final")
        rows[kind] = (m_eval, m_final)
        emit(f"sec441/eval-5adapters/{kind}", m_eval.means["e2e"] * 1e6,
             stage_row(m_eval))
        emit(f"sec441/final-base/{kind}", m_final.means["e2e"] * 1e6,
             f"ttft={m_final.means['ttft']*1e6:.0f}us "
             f"hit={m_final.means['cache_hit_frac']:.2f}")
    sp = speedup_table(rows["lora"][0], rows["alora"][0])
    emit("sec441/speedup-eval", 0.0, fmt_speedups(sp))


# ---------------------------------------------------------------------------
# adapter-churn leg (dynamic adapter lifecycle)
# ---------------------------------------------------------------------------
def _churn_workload(eng, *, n_adapters: int, reps: int, prompt_len: int,
                    gen_len: int, seed: int):
    rng = np.random.RandomState(seed)
    rids = []
    k = 0
    for rep in range(reps):
        for i in range(n_adapters):
            inv = list(eng.adapters[f"ad{i}"].spec.invocation_tokens)
            prompt = list(rng.randint(10, 400, prompt_len)) + inv
            rids.append(eng.submit(prompt, gen_len,
                                   adapter_name=f"ad{i}",
                                   arrival_time=1e-9 * k))
            k += 1
    steps, times, occ = 0, [], []
    while eng.pending or eng.waiting or eng.running:
        dt = eng.step()
        n_d, n_p = eng.last_step_tokens
        if n_d or n_p:
            steps += 1
            times.append(dt)
            occ.append(eng.adapter_pool.occupancy)
    return rids, steps, times, occ


def run_churn(arch: str, smoke: bool = False):
    n_adapters = 4 if smoke else 8
    slots = 2 if smoke else 3
    prompt_len = 24 if smoke else 64
    gen_len = 6 if smoke else 16
    reps = 2 if smoke else 3
    kw = dict(n_adapters=n_adapters, reps=reps, prompt_len=prompt_len,
              gen_len=gen_len)

    # all-resident sequential oracle for token-identity
    eng_o = make_engine("alora", n_adapters=n_adapters, arch=arch,
                        ecfg=EngineConfig(max_running=4,
                                          execution_mode="sequential"))
    rids_o, *_ = _churn_workload(eng_o, seed=7, **kw)
    oracle = [eng_o.request(r).output_tokens for r in rids_o]

    def mk():
        return make_engine("alora", n_adapters=n_adapters, arch=arch,
                           ecfg=EngineConfig(max_running=4,
                                             adapter_slots=slots))

    eng = mk()
    _churn_workload(eng, seed=999, **kw)          # warmup (jit traces)
    compiles_before = runner_mod.jit_cache_size()
    eng = mk()                                    # fresh pool, warm jit
    calls_before = eng.runner.num_device_calls
    rids, steps, times, occ = _churn_workload(eng, seed=7, **kw)
    calls = eng.runner.num_device_calls - calls_before

    out = [eng.request(r).output_tokens for r in rids]
    assert out == oracle, "churn output diverged from all-resident oracle"
    assert calls == steps, (calls, steps)         # 1.0 device-calls/step
    recompiles = runner_mod.jit_cache_size() - compiles_before
    assert recompiles == 0, f"{recompiles} post-warmup recompiles"
    st = eng.adapter_pool_stats()
    assert st.evictions > 0, "churn never evicted — slots not scarce?"

    emit(f"adapter_churn/{arch}/step_latency",
         float(np.mean(times)) * 1e6,
         f"p50={np.median(times)*1e6:.0f}us steps={steps}")
    emit(f"adapter_churn/{arch}/device_calls_per_step", calls / steps,
         f"calls={calls} steps={steps} recompiles_after_warmup="
         f"{recompiles}")
    emit(f"adapter_churn/{arch}/adapter_pool",
         float(np.mean(occ)),
         f"slots={st.num_slots} registered={st.num_registered} "
         f"prefetch={st.prefetch_issued}/{st.prefetch_hits}hit "
         f"installs={st.installs} evictions={st.evictions} "
         f"stalled={st.stalled_installs} queued_on_slots="
         f"{st.acquire_fails}")

    os.makedirs(RESULTS, exist_ok=True)
    rec = dict(arch=arch, smoke=smoke, n_adapters=n_adapters,
               steps=steps, device_calls_per_step=calls / steps,
               recompiles_after_warmup=recompiles,
               occupancy_mean=float(np.mean(occ)), **st.row())
    with open(os.path.join(RESULTS, "adapter_pool.jsonl"), "a") as f:
        f.write(json.dumps(rec) + "\n")


# ---------------------------------------------------------------------------
# Zipf thousand-adapter scheduling leg (affinity admission vs FCFS oracle)
# ---------------------------------------------------------------------------
def _zipf_trace(n_adapters: int, n_requests: int, alpha: float, seed: int):
    """Deterministic Zipf-popularity adapter index per request: adapter
    i has probability ∝ 1/(i+1)^alpha — a handful of hot adapters carry
    most traffic, a long cold tail carries the rest (S-LoRA's
    thousand-adapter regime)."""
    w = 1.0 / np.arange(1, n_adapters + 1, dtype=np.float64) ** alpha
    rng = np.random.RandomState(seed)
    return rng.choice(n_adapters, size=n_requests, p=w / w.sum())


def _zipf_workload(eng, adapter_ids, *, prompt_len: int, gen_len: int,
                   seed: int):
    """Submit the trace as a deep queue (all arrivals ~t=0, arrival
    order = trace order) and drain; returns (rids, steps, times,
    admit_step) where ``admit_step[req_id]`` is the scheduler step at
    which the request was first admitted — the DETERMINISTIC queue-wait
    measure (every request arrives before step 0, so the admission step
    IS its wait in steps; wall-clock queue seconds ride the same virtual
    clock as everything else but are noise-prone on shared CI hosts)."""
    rng = np.random.RandomState(seed)
    rids = []
    for k, i in enumerate(adapter_ids):
        inv = list(eng.adapters[f"ad{i}"].spec.invocation_tokens)
        prompt = list(rng.randint(10, 400, prompt_len)) + inv
        rids.append(eng.submit(prompt, gen_len, adapter_name=f"ad{i}",
                               arrival_time=1e-9 * k))
    steps, times = 0, []
    admit_step = {}
    while eng.pending or eng.waiting or eng.running:
        dt = eng.step()
        for r in eng.running:
            admit_step.setdefault(r.req_id, steps)
        n_d, n_p = eng.last_step_tokens
        if n_d or n_p:
            steps += 1
            times.append(dt)
    return rids, steps, times, admit_step


def run_zipf(arch: str, smoke: bool = False):
    # max_running deliberately exceeds adapter slots: the affinity
    # scheduler fills the extra run capacity with requests sharing the
    # (Zipf-hot) pinned adapters, while strict FCFS idles it whenever
    # the queue head needs a slot no eviction can free — that idling is
    # where the measured queue-latency win comes from
    n_adapters = 32 if smoke else 1000
    n_requests = 72 if smoke else 300
    slots = 2 if smoke else 6
    budget = 2 if smoke else 4
    max_running = 6 if smoke else 12
    prompt_len = 24 if smoke else 48
    gen_len = 8 if smoke else 12
    alpha = 1.2
    ids = _zipf_trace(n_adapters, n_requests, alpha, seed=11)
    kw = dict(prompt_len=prompt_len, gen_len=gen_len, seed=7)

    def mk(policy):
        return make_engine(
            "alora", n_adapters=n_adapters, arch=arch,
            ecfg=EngineConfig(
                max_running=max_running,
                adapter_slots=slots,
                adapter_staging_budget=budget,
                admission_policy=policy))

    # jit warmup over the full trace shape, once per policy — admission
    # order changes batch composition, so each policy can hit different
    # padded-bucket shapes.  Fresh engines below reuse the warm traces
    # (only the prompt-content seed differs), so the measured virtual
    # clocks are compute, not compilation.
    for policy in ("fcfs", "affinity"):
        _zipf_workload(mk(policy), ids,
                       prompt_len=prompt_len, gen_len=gen_len, seed=999)
    compiles_before = runner_mod.jit_cache_size()

    # FCFS oracle, then the affinity scheduler, on the SAME trace
    runs = {}
    for policy in ("fcfs", "affinity"):
        eng = mk(policy)
        calls_before = eng.runner.num_device_calls
        rids, steps, times, admit = _zipf_workload(eng, ids, **kw)
        calls = eng.runner.num_device_calls - calls_before
        assert calls == steps, (policy, calls, steps)   # 1.0 calls/step
        runs[policy] = dict(eng=eng, rids=rids, steps=steps, times=times,
                            st=eng.adapter_pool_stats(),
                            queue=eng.metrics_for(rids).means["queue"],
                            wait=float(np.mean([admit[r] for r in rids])))
    recompiles = runner_mod.jit_cache_size() - compiles_before
    assert recompiles == 0, f"{recompiles} post-warmup recompiles"

    # the measured win: adapter-affinity admission strictly reduces the
    # slot-contention failure modes AND queueing latency vs strict FCFS.
    # The latency comparison is in scheduler steps (deterministic on the
    # fixed trace); the virtual-clock seconds are emitted alongside.
    f, a = runs["fcfs"], runs["affinity"]
    assert a["st"].acquire_fails < f["st"].acquire_fails, \
        (a["st"].acquire_fails, f["st"].acquire_fails)
    assert a["st"].stalled_installs < f["st"].stalled_installs, \
        (a["st"].stalled_installs, f["st"].stalled_installs)
    assert a["wait"] < f["wait"], (a["wait"], f["wait"])
    # staging tier stayed bounded and never leaked a stage
    assert a["st"].staged_now == 0, a["st"].staged_now

    # equivalence oracle: with uncontended slots (one per registered
    # adapter) the two policies must produce token-for-token identical
    # outputs, whatever the admission order
    n_u = 6
    ids_u = [int(i) % n_u for i in ids[:24]]
    outs = {}
    for policy in ("fcfs", "affinity"):
        eng = make_engine(
            "alora", n_adapters=n_u, arch=arch,
            ecfg=EngineConfig(max_running=max_running,
                              adapter_slots=n_u,
                              admission_policy=policy))
        rids, *_ = _zipf_workload(eng, ids_u, **kw)
        outs[policy] = [eng.request(r).output_tokens for r in rids]
    assert outs["affinity"] == outs["fcfs"], \
        "affinity admission changed decoded tokens vs the FCFS oracle"

    for policy, r in runs.items():
        st = r["st"]
        emit(f"adapter_sched/{arch}/{policy}/queue_latency",
             r["queue"] * 1e6,
             f"wait_steps={r['wait']:.1f} steps={r['steps']} "
             f"acquire_fails={st.acquire_fails} "
             f"stalls={st.stalled_installs} installs={st.installs} "
             f"evictions={st.evictions} "
             f"staged_dropped={st.staged_dropped} "
             f"prefetch_deferred={st.prefetch_deferred}")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "adapter_sched.jsonl"), "a") as fh:
        for policy, r in runs.items():
            rec = dict(arch=arch, smoke=smoke, policy=policy,
                       n_adapters=n_adapters, n_requests=n_requests,
                       steps=r["steps"],
                       queue_wait_steps_mean=r["wait"],
                       queue_latency_mean=r["queue"],
                       step_latency_mean=float(np.mean(r["times"])),
                       **r["st"].row())
            fh.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3.2-8b")
    ap.add_argument("--churn", action="store_true",
                    help="adapter-lifecycle churn leg (N registered > "
                         "device slots)")
    ap.add_argument("--zipf", action="store_true",
                    help="Zipf thousand-adapter scheduling leg (affinity "
                         "admission vs the FCFS oracle)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI smoke runs")
    args = ap.parse_args()
    if args.churn:
        run_churn(args.arch, smoke=args.smoke)
    if args.zipf:
        run_zipf(args.arch, smoke=args.smoke)
    if not (args.churn or args.zipf):
        run()
