"""Paper Fig. 10: base→adapter→base, varying the FIRST base call's
generation length.

Prefix caching doesn't distinguish prefilled from generated blocks
(§4.4), so speedups track total context length; queueing delays from
LoRA prefills hit the second base call's TTFT.
"""
from __future__ import annotations

from benchmarks.common import emit, make_engine, stage_row
from repro.serving import pipelines as P
from repro.serving.metrics import fmt_speedups, speedup_table

GEN_LENS = [16, 48, 96, 192]


def run():
    for glen in GEN_LENS:
        rows = {}
        for kind in ("lora", "alora"):
            for seed in (9990 + glen, glen):      # warmup + measured
                eng = make_engine(kind)
                res = P.base_adapter(eng, adapter_names=["ad0"],
                                     prompt_len=48, gen_len=glen,
                                     eval_len=8, batch=2,
                                     feed_back_to_base=True, seed=seed)
            m_eval = res.stage_metrics(eng, "eval")
            m_final = res.stage_metrics(eng, "final")
            rows[kind] = (m_eval, m_final)
            emit(f"fig10/eval/{kind}/gen{glen}",
                 m_eval.means["e2e"] * 1e6, stage_row(m_eval))
            emit(f"fig10/final-base/{kind}/gen{glen}",
                 m_final.means["e2e"] * 1e6,
                 f"ttft={m_final.means['ttft']*1e6:.0f}us "
                 f"hit={m_final.means['cache_hit_frac']:.2f}")
        sp = speedup_table(rows["lora"][0], rows["alora"][0])
        emit(f"fig10/speedup-eval/gen{glen}", 0.0, fmt_speedups(sp))


if __name__ == "__main__":
    run()
