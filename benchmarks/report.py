"""Render the EXPERIMENTS.md tables from the dry-run JSONL artifacts.

  PYTHONPATH=src python -m benchmarks.report            # markdown to stdout
"""
from __future__ import annotations

import json
import os

BASE = os.path.join(os.path.dirname(__file__), "..", "results")


def fmt(v, spec=".2f"):
    """NaN/None-safe cell formatter: empty pipeline stages aggregate to
    NaN (``MetricsAggregate.row``) and must render as ``-``, not crash
    the report."""
    if v is None or (isinstance(v, float) and v != v):
        return "-"
    return format(v, spec)


def load(path):
    recs = []
    if os.path.exists(path):
        with open(path) as f:
            recs = [json.loads(line) for line in f]
    return recs


def fmt_mem(r):
    return r["memory"].get("temp_size_in_bytes", 0) / 2 ** 30


def roofline_table(recs, title):
    print(f"\n### {title}\n")
    print("| arch | shape | compute (s) | memory (s) | collective (s) | "
          "dominant | useful | temp GiB/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["shape"], r["arch"])):
        if not r["ok"]:
            print(f"| {r['arch']} | {r['shape']} | — | — | — | FAILED | "
                  f"— | — |")
            continue
        ro = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3e} | "
              f"{ro['memory_s']:.3e} | {ro['collective_s']:.3e} | "
              f"**{ro['dominant']}** | {ro['useful_flops_ratio']:.2f} | "
              f"{fmt_mem(r):.1f} |")


def dryrun_matrix(pod, multipod):
    print("\n### Dry-run matrix (lower+compile status)\n")
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    archs = sorted({r["arch"] for r in pod})
    idx = {(r["arch"], r["shape"], r["mesh"]): r for r in pod + multipod}
    print("| arch | " + " | ".join(f"{s} 16×16 / 2×16×16" for s in shapes)
          + " |")
    print("|---|" + "---|" * len(shapes))
    for a in archs:
        cells = []
        for s in shapes:
            p = idx.get((a, s, "16x16"))
            m = idx.get((a, s, "2x16x16"))
            cell = ("✓" if p and p["ok"] else "✗") + " / " + \
                   ("✓" if m and m["ok"] else "✗")
            cells.append(cell)
        print(f"| {a} | " + " | ".join(cells) + " |")


def perf_table(perf, base_idx):
    print("\n### §Perf variants vs baseline\n")
    print("| arch | shape | variant | compute | memory | collective | "
          "dominant | temp GiB |")
    print("|---|---|---|---|---|---|---|---|")
    for r in perf:
        if not r["ok"]:
            print(f"| {r['arch']} | {r['shape']} | {r.get('tag')} | "
                  f"FAILED: {r.get('error','')[:60]} | | | | |")
            continue
        b = base_idx.get((r["arch"], r["shape"], "16x16"))
        ro, bo = r["roofline"], b["roofline"] if b else None

        def delta(k):
            if not bo or not bo[k]:
                return f"{ro[k]:.3e}"
            return f"{ro[k]:.3e} ({ro[k]/bo[k]:.2f}×)"

        row_base = f"| {r['arch']} | {r['shape']} | baseline | " \
            f"{bo['compute_s']:.3e} | {bo['memory_s']:.3e} | " \
            f"{bo['collective_s']:.3e} | {bo['dominant']} | " \
            f"{fmt_mem(b):.1f} |" if bo else ""
        if row_base:
            print(row_base)
        print(f"| {r['arch']} | {r['shape']} | **{r.get('tag')}** | "
              f"{delta('compute_s')} | {delta('memory_s')} | "
              f"{delta('collective_s')} | {ro['dominant']} | "
              f"{fmt_mem(r):.1f} |")


def adapter_pool_table(recs):
    """Adapter-lifecycle counters from the churn benchmark
    (``bench_multi_adapter.py --churn`` appends one record per run)."""
    print("\n### Adapter pool — lifecycle counters (churn runs)\n")
    print("| arch | slots | registered | calls/step | recompiles | "
          "prefetch iss/hit | installs | evictions | stalled | "
          "occupancy |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        print(f"| {r['arch']} | {r['num_slots']:.0f} | "
              f"{r['num_registered']:.0f} | "
              f"{r['device_calls_per_step']:.2f} | "
              f"{r['recompiles_after_warmup']} | "
              f"{r['prefetch_issued']:.0f}/{r['prefetch_hits']:.0f} | "
              f"{r['installs']:.0f} | {r['evictions']:.0f} | "
              f"{r['stalled_installs']:.0f} | "
              f"{r['occupancy_mean']:.2f} |")


def adapter_sched_table(recs):
    """Admission-scheduling comparison from the Zipf thousand-adapter
    leg (``bench_multi_adapter.py --zipf`` appends one record per
    policy): the adapter-affinity scheduler vs the strict-FCFS oracle
    on the same trace.  Queue wait is in scheduler steps (deterministic
    on the fixed trace); acquire-fails/stalls/installs are the
    slot-contention failure modes affinity admission exists to avoid."""
    print("\n### Admission scheduling — affinity vs FCFS (Zipf trace)\n")
    print("| arch | policy | adapters | requests | steps | "
          "queue wait (steps) | acquire fails | stalls | installs | "
          "evictions | staged dropped |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["policy"])):
        print(f"| {r['arch']} | {r['policy']} | "
              f"{r['n_adapters']} | {r['n_requests']} | {r['steps']} | "
              f"{r['queue_wait_steps_mean']:.1f} | "
              f"{r['acquire_fails']:.0f} | {r['stalled_installs']:.0f} | "
              f"{r['installs']:.0f} | {r['evictions']:.0f} | "
              f"{r['staged_dropped']:.0f} |")


def sharded_step_table(recs):
    """TP-sharded mixed-step runs (``bench_mixed_batch.py --mesh …``
    appends one record per run).  Latency vs the single-device mixed
    baseline of the same invocation; on host meshes the ratio gauges
    collective overhead, not TP speedup."""
    print("\n### Sharded mixed step — host-mesh runs\n")
    print("| arch | mesh (data×model) | tok-shard | step (us) | "
          "single-dev (us) | ratio | assembly (us) | calls/step | "
          "recompiles |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        ratio = r["step_latency_us"] / r["baseline_us"] \
            if r.get("baseline_us") and r.get("step_latency_us") \
            is not None else float("nan")
        print(f"| {r['arch']} | {r['mesh']} | "
              f"{'✓' if r.get('data_shard') else '—'} | "
              f"{fmt(r.get('step_latency_us'), '.0f')} | "
              f"{fmt(r.get('baseline_us'), '.0f')} | "
              f"{fmt(ratio)}× | "
              f"{fmt(r.get('assembly_us_per_step'), '.0f')} | "
              f"{fmt(r.get('device_calls_per_step'))} | "
              f"{r['recompiles_after_warmup']} |")


def router_table(recs):
    """Multi-replica router runs (``bench_router.py`` appends one record
    per replicas × policy).  Fleet throughput uses the merged makespan
    (overlapped replica wall-clock counted once); the hit rate is the
    fleet's summed hits over summed lookups.  The affinity-vs-
    round_robin contrast at the same R is the routing win."""
    print("\n### Multi-replica router — affinity vs round_robin\n")
    print("| arch | R | policy | fleet hit rate | fleet tok/s | "
          "mean ttft (s) | per-replica n | reroutes |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["replicas"],
                                         r["policy"])):
        per_n = "/".join(str(n) for n in r.get("per_replica_n", []))
        print(f"| {r['arch']} | {r['replicas']} | {r['policy']} | "
              f"{fmt(r.get('fleet_hit_rate'))} | "
              f"{fmt(r.get('fleet_tok_per_s'), '.0f')} | "
              f"{fmt(r.get('mean_ttft_s'), '.4f')} | {per_n or '—'} | "
              f"{r.get('reroutes', 0)} |")


def d2h_table(recs):
    """Device→host payloads from the runner's ``log_d2h`` ring
    (``bench_mixed_batch.py`` appends one obs record per measured
    mode).  The paper-critical row is tag ``step``: sampled int32 ids
    only, a handful of elements per step — never ``(R, vocab)``
    logits."""
    print("\n### D2H payloads — runner `log_d2h` ring\n")
    print("| arch | mode | tag | transfers | elems | KiB | elems/step |")
    print("|---|---|---|---|---|---|---|")
    for r in recs:
        steps = max(r.get("steps", 0), 1)
        for tag in sorted(r.get("d2h", {})):
            row = r["d2h"][tag]
            print(f"| {r['arch']} | {r['mode']} | {tag} | "
                  f"{row['count']:.0f} | {row['elems']:.0f} | "
                  f"{row['bytes'] / 1024:.1f} | "
                  f"{row['elems'] / steps:.1f} |")


def reuse_table(recs):
    """Cache-reuse ledger rolled up per adapter (the paper's central
    quantity): tokens whose KV the admission probe reused from another
    adapter's (or the base model's) cache vs tokens it had to
    recompute."""
    rows = [(r, uid) for r in recs for uid in sorted(r.get("reuse", {}))]
    if not rows:
        return
    print("\n### Cache-reuse ledger — per adapter\n")
    print("| arch | mode | adapter | admissions | tok reused | "
          "tok recomputed | reuse frac | state reuses |")
    print("|---|---|---|---|---|---|---|---|")
    for r, uid in rows:
        row = r["reuse"][uid]
        print(f"| {r['arch']} | {r['mode']} | {uid} | "
              f"{row['admissions']:.0f} | {row['reused']:.0f} | "
              f"{row['recomputed']:.0f} | {row['reuse_frac']:.2f} | "
              f"{row['state_reuses']:.0f} |")


def trace_overhead_table(recs):
    """Tracer on/off A-B (``bench_mixed_batch.py --trace-check``): the
    observability layer's cost against its <2% budget."""
    print("\n### Tracing overhead — tracer on vs off\n")
    print("| arch | traced (us) | untraced (us) | overhead | events |")
    print("|---|---|---|---|---|")
    for r in recs:
        print(f"| {r['arch']} | {fmt(r.get('traced_us'), '.0f')} | "
              f"{fmt(r.get('untraced_us'), '.0f')} | "
              f"{fmt(r.get('overhead_pct'))}% | "
              f"{r.get('events', 0)} |")


def audit_table(recs):
    """Compiled-step audit summary (``python -m repro.analysis`` appends
    one record per config × mesh).  "donated HBM" is the pool footprint
    XLA aliases in-place thanks to ``donate_argnums`` — without donation
    that many bytes would be allocated a second time every step."""
    print("\n### Compiled-step invariant audit\n")
    print("| arch | mesh | status | donated outputs | donated HBM (KiB) "
          "| output HBM (KiB) | collectives | sync≡async |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["mesh"])):
        mem = r.get("memory") or {}
        alias_kib = mem.get("alias_size_bytes")
        out_kib = mem.get("output_size_bytes")
        colls = ", ".join(f"{k}×{v}"
                          for k, v in sorted(
                              r["fingerprint"]["counts"].items())) or "—"
        status = "ok" if r["ok"] else \
            f"**FAIL** ({len(r['violations'])} violation(s)" + \
            (", fingerprint drift)" if r.get("fingerprint_drift")
             else ")")
        print(f"| {r['arch']} | {r['mesh']} | {status} | "
              f"{', '.join(r['donated']) or '—'} | "
              f"{fmt(alias_kib / 1024 if alias_kib is not None else None, '.0f')} | "
              f"{fmt(out_kib / 1024 if out_kib is not None else None, '.0f')} | "
              f"{colls} | "
              f"{'✓' if r.get('sync_async_identical') else '✗'} |")


def static_pass_table(recs):
    """Static-analysis summary records (``python -m repro.analysis
    --json`` appends one per pass): the hot-path lint (Pass B) and the
    resource-lifecycle check (Pass C).  A red row here means the
    scheduler can leak KV blocks / state slots / adapter pins / staged
    weights on some exit path — the class of bug behind five historical
    incidents."""
    print("\n### Static analysis — hot-path lint + lifecycle check\n")
    print("| pass | status | violations |")
    print("|---|---|---|")
    names = {"hotpath_lint": "hot-path lint (Pass B)",
             "lifecycle_check": "resource lifecycle (Pass C)"}
    for r in sorted(recs, key=lambda r: r["kind"]):
        first = r["violations"][0] if r.get("violations") else ""
        status = "ok" if r["ok"] else \
            f"**FAIL** ({r.get('n_violations', len(r.get('violations', [])))})"
        print(f"| {names.get(r['kind'], r['kind'])} | {status} | "
              f"{first or '—'} |")


def main():
    pod = load(os.path.join(BASE, "dryrun_all.jsonl"))
    # dedup: last record per key wins
    seen = {}
    for r in pod:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    pod = list(seen.values())
    multipod = load(os.path.join(BASE, "dryrun_multipod.jsonl"))
    perf = load(os.path.join(BASE, "perf.jsonl"))
    dryrun_matrix(pod, multipod)
    roofline_table([r for r in pod if r["mesh"] == "16x16"],
                   "Roofline — single pod (16×16), baseline")
    if perf:
        base_idx = {(r["arch"], r["shape"], r["mesh"]): r for r in pod}
        perf_table(perf, base_idx)
    pool = load(os.path.join(BASE, "adapter_pool.jsonl"))
    if pool:
        # append-mode artifact: last record per (arch, smoke) wins
        latest = {}
        for r in pool:
            latest[(r["arch"], r["smoke"])] = r
        adapter_pool_table(list(latest.values()))
    sched = load(os.path.join(BASE, "adapter_sched.jsonl"))
    if sched:
        # append-mode artifact: last record per (arch, policy, smoke)
        # wins
        latest = {}
        for r in sched:
            latest[(r["arch"], r["policy"], r["smoke"])] = r
        adapter_sched_table(list(latest.values()))
    sharded = load(os.path.join(BASE, "sharded_step.jsonl"))
    if sharded:
        # append-mode artifact: last record per
        # (arch, mesh, smoke, data_shard) wins
        latest = {}
        for r in sharded:
            latest[(r["arch"], r["mesh"], r["smoke"],
                    r.get("data_shard", False))] = r
        sharded_step_table(list(latest.values()))
    router = load(os.path.join(BASE, "router.jsonl"))
    if router:
        # append-mode artifact: last record per
        # (arch, replicas, policy, smoke) wins
        latest = {}
        for r in router:
            latest[(r["arch"], r["replicas"], r["policy"],
                    r["smoke"])] = r
        router_table(list(latest.values()))
    obs = load(os.path.join(BASE, "obs.jsonl"))
    if obs:
        # append-mode artifact: last record per (arch, smoke, mode) wins
        latest = {}
        for r in obs:
            latest[(r["arch"], r["smoke"], r["mode"])] = r
        obs = sorted(latest.values(),
                     key=lambda r: (r["arch"], r["mode"]))
        d2h_table(obs)
        reuse_table(obs)
    overhead = load(os.path.join(BASE, "trace_overhead.jsonl"))
    if overhead:
        # append-mode artifact: last record per (arch, smoke) wins
        latest = {}
        for r in overhead:
            latest[(r["arch"], r["smoke"])] = r
        trace_overhead_table(sorted(latest.values(),
                                    key=lambda r: r["arch"]))
    audit = load(os.path.join(BASE, "analysis_audit.jsonl"))
    if audit:
        # the append-mode artifact interleaves compiled-step records
        # (keyed arch × mesh) with static-pass summary records (keyed
        # by pass kind, from --json); split before deduping
        compiled = [r for r in audit if "arch" in r]
        static = [r for r in audit
                  if r.get("kind") in ("hotpath_lint",
                                       "lifecycle_check")]
        if compiled:
            # last record per (arch, mesh) wins
            latest = {}
            for r in compiled:
                latest[(r["arch"], r["mesh"])] = r
            audit_table(list(latest.values()))
        if static:
            # last record per pass wins
            latest = {}
            for r in static:
                latest[r["kind"]] = r
            static_pass_table(list(latest.values()))


if __name__ == "__main__":
    main()
