"""Regression tests for scheduler/block accounting and the unified
mixed-batch execution path.

Covers the bugfix suite of the mixed-batch PR:
 1. a failed admission releases EVERYTHING it acquired (cache-matched
    blocks, partial fresh allocations, state-snapshot refs);
 2. duplicate-content blocks are remapped onto the canonical block and
    the duplicate released (dedup actually frees memory);
 3. chunked prefill never silently overdraws max_batched_tokens when
    decodes consumed the budget; the no-decode minimum-progress grant is
    charged to the next step;
 4. the mixed-batch path is token-for-token identical to the sequential
    path across base/aLoRA/LoRA mixes and preemption-recompute, and
    issues exactly ONE jitted device call per step.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.alora import AdapterSpec, init_adapter_weights
from repro.core.kv_manager import OutOfBlocks
from repro.models import init_params
from repro.serving import Engine, EngineConfig

KEY = jax.random.key(0)
INV = (7, 8, 9)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("granite-3.2-8b")
    params = init_params(KEY, cfg)
    alora = init_adapter_weights(jax.random.key(7), cfg, 8)
    lora = init_adapter_weights(jax.random.key(8), cfg, 8)
    return cfg, params, alora, lora


def mk_engine(setup, **ecfg_kw):
    cfg, params, alora, lora = setup
    ads = [(AdapterSpec("uq", rank=8, invocation_tokens=INV), alora),
           (AdapterSpec("lm", rank=8, invocation_tokens=None), lora)]
    return Engine(cfg, params, adapters=ads,
                  engine_cfg=EngineConfig(**ecfg_kw))


def prompt_of(n, seed=0, vocab=500):
    return list(np.random.RandomState(seed).randint(10, vocab, n))


# ---------------------------------------------------------------------------
# 1. admission failure must not leak blocks
# ---------------------------------------------------------------------------
class TestAdmissionRollback:
    def test_failed_admit_restores_free_count(self, setup):
        """Admission that fails the free-count check must release its
        cache-matched blocks (the req enters with acquired refs)."""
        eng = mk_engine(setup, num_blocks=16)
        p1 = prompt_of(48, seed=1)            # 3 blocks, cached at finish
        eng.submit(p1, 2)
        eng.run_until_idle()
        # drain the pool so the next admission cannot allocate
        held = [eng.kv_mgr.allocate()
                for _ in range(eng.kv_mgr.num_free() - 1)]
        free_before = eng.kv_mgr.num_free()
        rid = eng.submit(p1 + prompt_of(64, seed=2), 2)
        assert not eng._try_admit(eng.request(rid))
        assert eng.kv_mgr.num_free() == free_before
        # every cached block's ref must be back to 0
        assert all(eng.kv_mgr.meta[b].ref == 1 for b in held)
        eng.kv_mgr.release_all(held)

    def test_failed_allocate_rolls_back_partial(self, setup, monkeypatch):
        """OutOfBlocks mid-allocation must release the partially
        allocated fresh blocks AND the cache-matched ones."""
        eng = mk_engine(setup, num_blocks=32)
        p1 = prompt_of(48, seed=1)
        eng.submit(p1, 2)
        eng.run_until_idle()
        free_before = eng.kv_mgr.num_free()
        orig = eng.kv_mgr.allocate
        calls = []

        def flaky():
            if calls:                          # fail on the 2nd fresh block
                raise OutOfBlocks("injected")
            calls.append(1)
            return orig()

        monkeypatch.setattr(eng.kv_mgr, "allocate", flaky)
        rid = eng.submit(p1 + prompt_of(64, seed=2), 2)
        assert not eng._try_admit(eng.request(rid))
        monkeypatch.undo()
        assert eng.kv_mgr.num_free() == free_before
        assert calls                           # the branch was exercised

    def test_failed_admit_releases_state_slot(self, setup, monkeypatch):
        """Hybrid archs: a KV-side failure must drop the acquired SSM
        state-snapshot ref too."""
        cfg = get_reduced("zamba2-2.7b")
        params = init_params(jax.random.key(1), cfg)
        w = init_adapter_weights(jax.random.key(7), cfg, 8)
        spec = AdapterSpec("uq", rank=8, invocation_tokens=INV)
        eng = Engine(cfg, params, adapters=[(spec, w)],
                     engine_cfg=EngineConfig(num_blocks=32))
        p1 = prompt_of(48, seed=1, vocab=cfg.vocab_size)
        eng.submit(p1, 2)
        eng.run_until_idle()
        st_free_before = eng.st_mgr.num_free()
        kv_free_before = eng.kv_mgr.num_free()
        monkeypatch.setattr(eng.kv_mgr, "allocate",
                            lambda: (_ for _ in ()).throw(
                                OutOfBlocks("injected")))
        rid = eng.submit(p1 + prompt_of(64, seed=2,
                                        vocab=cfg.vocab_size), 2)
        assert not eng._try_admit(eng.request(rid))
        monkeypatch.undo()
        assert eng.st_mgr.num_free() == st_free_before
        assert eng.kv_mgr.num_free() == kv_free_before


# ---------------------------------------------------------------------------
# 2. dedup remaps onto the canonical block and frees the duplicate
# ---------------------------------------------------------------------------
def test_dedup_releases_duplicate_blocks(setup):
    """Two identical prompts admitted in the same step each allocate
    their own blocks; registration must collapse them onto one canonical
    set with ref == 2 and return the duplicates to the pool."""
    eng = mk_engine(setup)
    p = prompt_of(48, seed=4)                  # exactly 3 full blocks
    r1 = eng.submit(p, 4)
    r2 = eng.submit(p, 4)
    eng.step()                                 # both admitted + prefilled
    req1, req2 = eng.request(r1), eng.request(r2)
    assert req1.block_ids[:3] == req2.block_ids[:3]
    for b in req1.block_ids[:3]:
        assert eng.kv_mgr.meta[b].ref == 2
    eng.run_until_idle()
    assert req1.output_tokens == req2.output_tokens


# ---------------------------------------------------------------------------
# 3. the prefill budget respects max_batched_tokens
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["mixed", "sequential"])
def test_budget_cap_not_violated(setup, mode):
    """With decodes eating the budget, prefill must wait instead of
    overdrawing; without decodes the one-block grant is charged to the
    next step (two-step windows stay within 2×cap + one block)."""
    M = 20
    eng = mk_engine(setup, max_batched_tokens=M, execution_mode=mode)
    rids = [eng.submit(prompt_of(16, seed=i), 12) for i in range(6)]
    # warm the decodes past prefill before the long request arrives
    for _ in range(3):
        eng.step()
    rids.append(eng.submit(prompt_of(96, seed=9), 4))
    saw_decode_step = False
    prev_tokens = 0
    for _ in range(400):
        if not (eng.waiting or eng.running or eng.pending):
            break
        eng.step()
        n_d, n_p = eng.last_step_tokens
        if n_d > 0:
            saw_decode_step = True
            assert n_d + n_p <= M, (n_d, n_p)
        else:
            # minimum-progress grant may overdraw by < one block...
            assert n_p <= max(M, eng.ecfg.block_size)
        # ...but consecutive steps must amortize back under the cap
        assert prev_tokens + n_d + n_p <= 2 * M + eng.ecfg.block_size
        prev_tokens = n_d + n_p
    assert saw_decode_step
    for r in rids:
        assert len(eng.request(r).output_tokens) > 0


# ---------------------------------------------------------------------------
# 4. mixed batch ≡ sequential, in one device call per step
# ---------------------------------------------------------------------------
def _run(setup, mode, *, num_blocks=512, staggered=False, **ecfg_kw):
    eng = mk_engine(setup, execution_mode=mode, num_blocks=num_blocks,
                    max_batched_tokens=64, **ecfg_kw)
    specs = [(prompt_of(40, seed=1), None),
             (prompt_of(52, seed=2) + list(INV), "uq"),
             (prompt_of(33, seed=3), "lm"),
             (prompt_of(40, seed=1), None)]    # dup prompt: dedup path
    rids = []
    for i, (p, name) in enumerate(specs):
        arrival = 1e-9 * i if staggered else None
        rids.append(eng.submit(p, 6, adapter_name=name,
                               arrival_time=arrival))
    eng.run_until_idle()
    return eng, [eng.request(r).output_tokens for r in rids]


def test_mixed_equals_sequential_adapter_mix(setup):
    eng_m, out_m = _run(setup, "mixed")
    eng_s, out_s = _run(setup, "sequential")
    assert eng_m.use_mixed and not eng_s.use_mixed
    assert eng_m.runner.call_counts["prefill_chunk"] == 0
    assert eng_m.runner.call_counts["decode_batch"] == 0
    assert eng_s.runner.call_counts["mixed_step"] == 0
    assert all(len(o) == 6 for o in out_m)
    assert out_m == out_s


def test_mixed_equals_sequential_under_preemption(setup):
    """A pool too small for the working set forces recompute-preemption;
    both paths must still emit identical tokens."""
    outs, preempts = [], []
    for mode in ("mixed", "sequential"):
        eng = mk_engine(setup, execution_mode=mode, num_blocks=10,
                        max_running=2)
        rids = [eng.submit(prompt_of(64, seed=i), 4) for i in range(3)]
        eng.run_until_idle()
        outs.append([eng.request(r).output_tokens for r in rids])
        preempts.append(eng.preemptions)
    assert outs[0] == outs[1]
    assert all(len(o) == 4 for o in outs[0])


def test_mixed_pallas_kernel_matches_ref(setup):
    """The Pallas ragged-attention kernel (interpret mode), plumbed
    through EngineConfig.mixed_attn_impl, must emit the same tokens as
    the jnp reference path."""
    outs = []
    for impl in ("ref", "pallas_interpret"):
        eng = mk_engine(setup, mixed_attn_impl=impl)
        rids = [eng.submit(prompt_of(24, seed=1), 3),
                eng.submit(prompt_of(20, seed=2) + list(INV), 3,
                           adapter_name="uq")]
        eng.run_until_idle()
        assert eng.runner.call_counts["mixed_step"] > 0
        outs.append([eng.request(r).output_tokens for r in rids])
    assert outs[0] == outs[1]


def test_one_device_call_per_mixed_step(setup):
    """A step mixing N prefilling and M decoding requests must issue
    exactly one jitted device call (vs N+1 on the sequential path)."""
    eng = mk_engine(setup, max_batched_tokens=256)
    eng.submit(prompt_of(40, seed=1), 8)
    eng.step()                                 # prefill-only step
    eng.step()                                 # decode-only step
    # now in decode; add two prefilling requests
    eng.submit(prompt_of(56, seed=2), 4)
    eng.submit(prompt_of(30, seed=3), 4, adapter_name="lm")
    before = eng.runner.num_device_calls
    eng.step()                                 # 1 decode + 2 prefills
    n_d, n_p = eng.last_step_tokens
    assert n_d == 1 and n_p == 86
    assert eng.runner.num_device_calls - before == 1

    # identical schedule on the sequential path: 1 decode batch + 2
    # prefill chunks = 3 device calls
    eng_s = mk_engine(setup, max_batched_tokens=256,
                      execution_mode="sequential")
    eng_s.submit(prompt_of(40, seed=1), 8)
    eng_s.step()
    eng_s.step()
    eng_s.submit(prompt_of(56, seed=2), 4)
    eng_s.submit(prompt_of(30, seed=3), 4, adapter_name="lm")
    before = eng_s.runner.num_device_calls
    eng_s.step()
    assert eng_s.runner.num_device_calls - before == 3


# ---------------------------------------------------------------------------
# 5. mixed ≡ sequential across architecture families (SSM / hybrid /
#    encoder-decoder) — every config runs the one-device-call step
# ---------------------------------------------------------------------------
ARCHS = ["mamba2-2.7b", "zamba2-2.7b", "whisper-large-v3"]


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    from repro.configs import get_reduced
    cfg = get_reduced(request.param)
    params = init_params(jax.random.key(1), cfg)
    alora = init_adapter_weights(jax.random.key(7), cfg, 8)
    lora = init_adapter_weights(jax.random.key(8), cfg, 8)
    return cfg, params, alora, lora


def mk_arch_engine(arch_setup, mode, **ecfg_kw):
    cfg, params, alora, lora = arch_setup
    ads = [(AdapterSpec("uq", rank=8, invocation_tokens=INV), alora),
           (AdapterSpec("lm", rank=8, invocation_tokens=None), lora)]
    return cfg, Engine(cfg, params, adapters=ads,
                       engine_cfg=EngineConfig(execution_mode=mode,
                                               **ecfg_kw))


def submit_kw(cfg, seed):
    """Extra submit args an encoder-decoder request needs: stub frame
    embeddings plus a content-digest cache salt."""
    if not cfg.is_encoder_decoder:
        return {}
    fr = np.random.RandomState(1000 + seed).randn(
        cfg.encoder_seq_len, cfg.d_model).astype(np.float32)
    return dict(frame_embeds=fr, salt=(seed,))


def test_mixed_equals_sequential_archs(arch_setup):
    """Base/aLoRA/LoRA mix must be token-identical across execution
    modes on SSM, hybrid and encoder-decoder configs, with the mixed
    path never touching the sequential step functions."""
    outs = []
    for mode in ("mixed", "sequential"):
        cfg, eng = mk_arch_engine(arch_setup, mode)
        specs = [(prompt_of(40, 1, cfg.vocab_size), None, 1),
                 (prompt_of(52, 2, cfg.vocab_size) + list(INV), "uq", 2),
                 (prompt_of(33, 3, cfg.vocab_size), "lm", 3)]
        rids = [eng.submit(p, 6, adapter_name=name, **submit_kw(cfg, s))
                for p, name, s in specs]
        eng.run_until_idle()
        outs.append([eng.request(r).output_tokens for r in rids])
        if mode == "mixed":
            assert eng.use_mixed
            assert eng.runner.call_counts["prefill_chunk"] == 0
            assert eng.runner.call_counts["decode_batch"] == 0
            assert eng.runner.call_counts["mixed_step"] > 0
        else:
            assert eng.runner.call_counts["mixed_step"] == 0
    assert all(len(o) == 6 for o in outs[0])
    assert outs[0] == outs[1]


def test_mixed_state_snapshot_reuse_archs(arch_setup):
    """SSM/hybrid: the mixed path must keep feeding (and consuming) the
    beyond-paper state-snapshot cache exactly like the sequential path."""
    cfg, *_ = arch_setup
    if cfg.ssm is None:
        pytest.skip("state snapshots are an SSM-arch feature")
    outs, hits = [], []
    for mode in ("mixed", "sequential"):
        cfg, eng = mk_arch_engine(arch_setup, mode)
        x = prompt_of(96, 1, cfg.vocab_size)
        r1 = eng.submit(x, 8)
        eng.run_until_idle()
        y = eng.request(r1).output_tokens
        r2 = eng.submit(x + y + list(INV), 4, adapter_name="uq")
        eng.run_until_idle()
        req = eng.request(r2)
        outs.append(req.output_tokens)
        hits.append((req.n_cache_hit_tokens, req.state_reused))
    assert outs[0] == outs[1]
    assert hits[0] == hits[1]
    assert hits[0][0] > 0 and hits[0][1]


def test_mixed_equals_sequential_preemption_archs(arch_setup):
    """Tiny block/state pools force recompute-preemption; both paths
    must still emit identical tokens on every arch family."""
    cfg, *_ = arch_setup
    outs, preempts = [], []
    for mode in ("mixed", "sequential"):
        cfg, eng = mk_arch_engine(arch_setup, mode, num_blocks=8,
                                  max_running=2, num_state_slots=6)
        rids = [eng.submit(prompt_of(64, i, cfg.vocab_size), 4,
                           **submit_kw(cfg, i)) for i in range(3)]
        eng.run_until_idle()
        outs.append([eng.request(r).output_tokens for r in rids])
        preempts.append(eng.preemptions)
        assert not eng._xkv          # encoder KV fully released
    assert outs[0] == outs[1]
    assert preempts[0] == preempts[1]
    if cfg.num_attn_layers() > 0:    # block-bearing archs must starve
        assert preempts[0] > 0
    assert all(len(o) == 4 for o in outs[0])


def test_mixed_ragged_ssd_pallas_matches_ref(arch_setup):
    """The interpret-mode Pallas ragged-SSD kernel, plumbed through
    EngineConfig.mixed_ssd_impl, must emit the same tokens as the jnp
    reference scan."""
    cfg, *_ = arch_setup
    if cfg.ssm is None:
        pytest.skip("ragged SSD scan is an SSM-arch path")
    outs = []
    for impl in ("ref", "pallas_interpret"):
        cfg, eng = mk_arch_engine(arch_setup, "mixed",
                                  mixed_ssd_impl=impl)
        rids = [eng.submit(prompt_of(24, 1, cfg.vocab_size), 3),
                eng.submit(prompt_of(20, 2, cfg.vocab_size) + list(INV),
                           3, adapter_name="uq")]
        eng.run_until_idle()
        outs.append([eng.request(r).output_tokens for r in rids])
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# 6. scheduler hot-path bugfix regressions
# ---------------------------------------------------------------------------
def test_decode_block_hashing_is_incremental(setup, monkeypatch):
    """Completing a decoded block must cost exactly ONE hash_block call
    (the chain extends from the cached parent; recomputing from token 0
    made long generations O(n²))."""
    import repro.serving.engine as engine_mod
    from repro.core.block_hash import request_block_hashes
    eng = mk_engine(setup)
    calls = []
    real = engine_mod.hash_block
    monkeypatch.setattr(engine_mod, "hash_block",
                        lambda *a, **k: calls.append(1) or real(*a, **k))
    rid = eng.submit(prompt_of(30, seed=1), 40)
    eng.run_until_idle()
    req = eng.request(rid)
    # positions 32/48/64 complete blocks 1/2/3 during decode
    n_new_blocks = len(req.all_tokens) // eng.ecfg.block_size - \
        len(req.prompt) // eng.ecfg.block_size
    assert len(calls) == n_new_blocks == 3
    # the incremental chain must equal a from-scratch recompute
    assert req.hashes == request_block_hashes(
        req.all_tokens[:64], eng.ecfg.block_size, req.adapter_key(),
        req.salt)


def test_preempt_releases_encoder_kv():
    """Preempting an encoder-decoder request must drop its cross-
    attention KV from the engine (re-admission re-encodes); a preempted-
    then-never-readmitted request must not leak it."""
    from repro.configs import get_reduced
    cfg = get_reduced("whisper-large-v3")
    params = init_params(jax.random.key(1), cfg)
    eng = Engine(cfg, params, engine_cfg=EngineConfig())
    rids = [eng.submit(prompt_of(20, seed=i, vocab=cfg.vocab_size), 4,
                       **{"frame_embeds": np.random.RandomState(i).randn(
                           cfg.encoder_seq_len, cfg.d_model
                       ).astype(np.float32), "salt": (i,)})
            for i in range(2)]
    eng.step()
    assert set(eng._xkv) == set(rids)
    victim = eng.running[-1]
    eng._preempt(victim)
    assert victim.req_id not in eng._xkv
    eng.run_until_idle()
    assert not eng._xkv


# ---------------------------------------------------------------------------
# 7. adapter-pool accounting (dynamic adapter lifecycle)
# ---------------------------------------------------------------------------
def test_preemption_releases_adapter_pin(setup):
    """Recompute-preemption must unpin the victim's adapter slot (it
    re-pins at re-admission); after drain every pin is back to zero and
    both execution modes emit identical tokens."""
    outs = []
    for mode in ("mixed", "sequential"):
        eng = mk_engine(setup, execution_mode=mode, num_blocks=8,
                        max_running=2, adapter_slots=1)
        # 61 + 3 invocation tokens = 64 = exactly 4 blocks: the first
        # decode token then needs a 5th block -> guaranteed starvation
        rids = [eng.submit(prompt_of(61, seed=i) + list(INV), 4,
                           adapter_name="uq") for i in range(2)]
        rids.append(eng.submit(prompt_of(64, seed=9), 4))
        eng.run_until_idle()
        assert eng.preemptions > 0
        assert eng.adapter_pool.pinned_slots() == 0
        outs.append([eng.request(r).output_tokens for r in rids])
    assert outs[0] == outs[1]
    assert all(len(o) == 4 for o in outs[0])


def test_budget_and_block_accounting_under_adapter_churn(setup):
    """The PR-1 accounting invariants (budget cap, leak-free admission)
    must hold while adapters cycle through a 1-slot pool."""
    M = 24
    eng = mk_engine(setup, max_batched_tokens=M, adapter_slots=1)
    free0 = eng.kv_mgr.num_free()
    rids = [eng.submit(prompt_of(20, seed=i) + list(INV), 6,
                       adapter_name=("uq" if i % 2 else "lm"))
            for i in range(5)]
    prev = 0
    for _ in range(400):
        if not (eng.waiting or eng.running or eng.pending):
            break
        eng.step()
        n_d, n_p = eng.last_step_tokens
        if n_d > 0:
            assert n_d + n_p <= M, (n_d, n_p)
        assert prev + n_d + n_p <= 2 * M + eng.ecfg.block_size
        prev = n_d + n_p
    for r in rids:
        assert len(eng.request(r).output_tokens) == 6
    assert eng.adapter_pool.evictions > 0       # both adapters cycled
    assert eng.adapter_pool.pinned_slots() == 0
    assert eng.kv_mgr.num_free() == free0       # no block leaks


def test_affinity_matches_fcfs_tokens_when_uncontended(setup):
    """With slots uncontended (every adapter fits resident) the affinity
    scheduler may still reorder admissions, but must emit exactly the
    strict-FCFS oracle's tokens per request — greedy decode is
    batch-composition independent, so reordering is invisible in the
    outputs."""
    outs = []
    for policy in ("fcfs", "affinity"):
        eng = mk_engine(setup, admission_policy=policy, adapter_slots=2,
                        max_running=3)
        rids = []
        for i in range(6):
            name = (None, "uq", "lm")[i % 3]
            p = prompt_of(28, seed=i) + (list(INV) if name == "uq" else [])
            rids.append(eng.submit(p, 4, adapter_name=name,
                                   arrival_time=1e-9 * i))
        eng.run_until_idle()
        outs.append([eng.request(r).output_tokens for r in rids])
        assert eng.adapter_pool.acquire_fails == 0   # truly uncontended
    assert outs[0] == outs[1]
    assert all(len(o) == 4 for o in outs[0])


def test_prefetch_window_survives_full_engine(setup):
    """Regression: the prefetch window used to be ``max_running -
    len(running)`` — a saturated engine issued ZERO prefetches, exactly
    when the queue-time H2D head start matters most.  Queued adapters
    must be staged while the engine is full, and the stage must be
    claimed (not leaked) once the request admits."""
    eng = mk_engine(setup, max_running=1, adapter_slots=2)
    eng.submit(prompt_of(24, seed=1), 16, arrival_time=0.0)
    eng.step()
    assert len(eng.running) == eng.ecfg.max_running
    rid = eng.submit(prompt_of(24, seed=2) + list(INV), 2,
                     adapter_name="uq", arrival_time=1e-9)
    eng.step()
    assert len(eng.running) == eng.ecfg.max_running  # still saturated
    pool = eng.adapter_pool
    assert pool.prefetch_issued >= 1    # staged despite full occupancy
    assert pool.affinity("uq") == 1     # weights on device, not resident
    eng.run_until_idle()
    assert len(eng.request(rid).output_tokens) == 2
    assert pool.staged_now == 0         # install claimed the stage
    assert pool.prefetch_hits >= 1
    assert pool.stalled_installs == 0


def test_out_of_order_submission_keeps_arrival_order(setup):
    """``pending`` is a deque kept sorted on arrival_time: out-of-order
    submission (replayed traces, router retries) must not let a later
    arrival jump the clock queue."""
    eng = mk_engine(setup)
    a = eng.submit(prompt_of(16, seed=1), 2, arrival_time=3e-9)
    b = eng.submit(prompt_of(16, seed=2), 2, arrival_time=1e-9)
    c = eng.submit(prompt_of(16, seed=3), 2, arrival_time=2e-9)
    assert [r.req_id for r in eng.pending] == [b, c, a]
    eng.run_until_idle()
    for rid in (a, b, c):
        assert len(eng.request(rid).output_tokens) == 2


# ---------------------------------------------------------------------------
# 8. scheduler starvation must not hold a partial block claim
# ---------------------------------------------------------------------------
def test_schedule_decodes_releases_partial_claim_on_starvation(setup,
                                                               monkeypatch):
    """A decode that cannot claim EVERY block it needs this step must
    release the ones it did claim: holding blocks it cannot use while
    admission and the other decodes starve forces needless
    recompute-preemptions.  Forced here with a two-block gap and an
    allocator that has exactly one block left."""
    eng = mk_engine(setup, async_submission=False)
    rid = eng.submit(prompt_of(47, seed=1), 40)
    for _ in range(4):                         # prefill + a few decodes
        eng.step()
    r = eng.request(rid)
    assert r.state.value == "decode" and not r.is_finished()
    # synthesize a speculative two-block gap (the shape a starved-then-
    # retried request builds up), then leave exactly ONE free block
    eng.kv_mgr.release(r.block_ids.pop())
    eng.kv_mgr.release(r.block_ids.pop())
    r.n_computed = len(r.block_ids) * eng.ecfg.block_size \
        + eng.ecfg.block_size + 1              # needs 2 more blocks
    held = [eng.kv_mgr.allocate()
            for _ in range(eng.kv_mgr.num_free() - 1)]
    free_before = eng.kv_mgr.num_free()
    assert free_before == 1
    n_blocks_before = len(r.block_ids)
    ok = eng._schedule_decodes()
    assert r not in ok                         # starved, skipped
    # the partial claim (1 of the 2 needed blocks) was released …
    assert eng.kv_mgr.num_free() == free_before
    # … and the request's table no longer references it
    assert len(r.block_ids) == n_blocks_before
    eng.kv_mgr.release_all(held)


# ---------------------------------------------------------------------------
# 9. async step pipeline (EngineConfig.async_submission)
# ---------------------------------------------------------------------------
def test_async_equals_sync_mixed(setup):
    """One-step-lookahead submission ≡ the synchronous mixed oracle,
    token for token, and still ≡ the sequential path."""
    eng_a, out_a = _run(setup, "mixed")                 # async default
    eng_s, out_s = _run(setup, "mixed", async_submission=False)
    eng_q, out_q = _run(setup, "sequential")
    assert eng_a.use_async and not eng_s.use_async and not eng_q.use_async
    assert out_a == out_s == out_q
    assert all(len(o) == 6 for o in out_a)


def test_async_placeholder_patched_and_finish_deferred(setup):
    """A submitted-but-unretired step leaves PENDING placeholders in
    output_tokens (position counts for scheduling, value still on
    device); the next step's retire patches them, and no placeholder
    survives a drain."""
    from repro.serving.engine import PENDING
    eng = mk_engine(setup)
    rid = eng.submit(prompt_of(20, seed=1), 3)
    eng.step()                 # prefill submitted, first token in flight
    req = eng.request(rid)
    assert req.output_tokens == [PENDING]
    eng.step()                 # decode submitted, prefill retired
    assert req.output_tokens[0] != PENDING
    assert req.output_tokens[-1] == PENDING
    eng.run_until_idle()
    assert PENDING not in req.output_tokens
    assert len(req.output_tokens) == 3
    assert req.state.value == "done"


def test_async_decode_blocks_still_cached(setup):
    """Deferred (retire-time) decode block hashing must still register
    generated blocks — paper §4.4 reuse of generated tokens holds under
    async submission, and the hash chain matches a from-scratch
    recompute."""
    from repro.core.block_hash import request_block_hashes
    eng = mk_engine(setup)
    rid = eng.submit(prompt_of(30, seed=1), 40)
    eng.run_until_idle()
    req = eng.request(rid)
    # positions 32/48/64 completed blocks 1/2/3 during decode
    assert req.hashes == request_block_hashes(
        req.all_tokens[:64], eng.ecfg.block_size, req.adapter_key(),
        req.salt)
    # a follow-up over the generated context hits those blocks
    r2 = eng.submit(req.all_tokens[:64] + prompt_of(8, seed=2), 2)
    eng.run_until_idle()
    assert eng.request(r2).n_cache_hit_tokens >= 48
