"""Per-architecture smoke tests (assignment requirement): reduced
variants of all 10 assigned archs — one forward pass and one train step
on CPU, asserting output shapes and finiteness, plus the
prefill+decode == full-forward consistency invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_reduced
from repro.models import (decode_step, forward_full, init_decode_caches,
                          init_params, logits_for)
from repro.models.layers import padded_vocab
from repro.models.model import Runtime, prefill_to_decode_caches
from repro.training import AdamWConfig, init_train_state, make_train_step

KEY = jax.random.key(0)


def extra_for(cfg, B, seed=0):
    rng = np.random.RandomState(seed)
    if cfg.frontend == "audio":
        return jnp.asarray(rng.randn(B, cfg.encoder_seq_len, cfg.d_model)
                           * 0.05, jnp.float32)
    if cfg.frontend == "vision":
        return jnp.asarray(rng.randn(B, cfg.num_patches, cfg.d_model)
                           * 0.05, jnp.float32)
    return None


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_reduced(arch)
            cache[arch] = (cfg, init_params(KEY, cfg))
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(models, arch):
    cfg, params = models(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    B, S = 2, 32
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    h, aux, _ = forward_full(params, cfg, toks,
                             extra_embeds=extra_for(cfg, B))
    n_prefix = cfg.num_patches if cfg.frontend == "vision" else 0
    assert h.shape == (B, S + n_prefix, cfg.d_model)
    logits = logits_for(params, cfg, h)
    assert logits.shape == (B, S + n_prefix, padded_vocab(cfg))
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step(models, arch):
    cfg, params = models(arch)
    B, S = 2, 32
    state = init_train_state(params)
    step = make_train_step(cfg, AdamWConfig(total_steps=10), Runtime(),
                           loss_chunk=16)
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    ex = extra_for(cfg, B)
    if ex is not None:
        batch["extra_embeds"] = ex
    state2, stats = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(stats["loss"]))
    assert bool(jnp.isfinite(stats["grad_norm"]))
    # every parameter leaf received a (finite, nonzero) update
    changed = [
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(state2.params))
    ]
    assert all(changed), f"{sum(changed)}/{len(changed)} leaves updated"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_consistency(models, arch):
    """KV-cache/state correctness: prefill S-1 tokens + decode token S
    must equal the teacher-forced forward at position S-1."""
    cfg, params = models(arch)
    B, S = 2, 33
    toks = jax.random.randint(jax.random.key(1), (B, S), 0,
                              cfg.vocab_size)
    ex = extra_for(cfg, B)
    h, _, _ = forward_full(params, cfg, toks, extra_embeds=ex)
    want = logits_for(params, cfg, h)[:, -1]

    h2, _, pc = forward_full(params, cfg, toks[:, :S - 1],
                             extra_embeds=ex, return_caches=True)
    npre = (S - 1) + (cfg.num_patches if cfg.frontend == "vision" else 0)
    dc = prefill_to_decode_caches(cfg, pc, npre, 128)
    got, _ = decode_step(params, cfg, toks[:, S - 1:S], dc, npre)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_ring_buffer():
    """Decode past the window: ring cache must equal a fresh prefill
    truncated to the window (starcoder2 family, native window)."""
    cfg = get_reduced("starcoder2-3b").replace(sliding_window=16)
    params = init_params(KEY, cfg)
    B, S = 1, 40
    toks = jax.random.randint(jax.random.key(2), (B, S + 1), 0,
                              cfg.vocab_size)
    # reference: full forward (window masking internal)
    h, _, _ = forward_full(params, cfg, toks)
    want = logits_for(params, cfg, h)[:, -1]
    # prefill S then decode 1 with ring cache
    _, _, pcaches = forward_full(params, cfg, toks[:, :S],
                                 return_caches=True)
    dc = prefill_to_decode_caches(cfg, pcaches, S, 64)
    got, _ = decode_step(params, cfg, toks[:, S:], dc, S)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
