"""aLoRA numerical semantics (paper §2.3):

* pre-activation tokens produce outputs IDENTICAL to the base model
  (bit-exact — this is what makes KV blocks interchangeable);
* post-activation tokens equal a fully-adapted (vanilla LoRA) forward;
* K/V of pre-activation tokens are unchanged even when later tokens are
  adapted (causality of the masked delta).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.alora import init_adapter_weights, stack_adapters
from repro.models import forward_full, init_params
from repro.models.layers import lora_delta, qkv_project

KEY = jax.random.key(0)


def setup(arch="granite-3.2-8b", rank=8):
    cfg = get_reduced(arch)
    params = init_params(KEY, cfg)
    w = init_adapter_weights(jax.random.key(5), cfg, rank)
    stacked = stack_adapters(cfg, [w], rank)
    return cfg, params, stacked


class TestLoraDelta:
    def test_zero_adapter_is_exact_zero(self):
        x = jax.random.normal(KEY, (7, 16))
        a = jnp.zeros((2, 16, 4))
        b = jax.random.normal(KEY, (2, 4, 24))
        idx = jnp.zeros((7,), jnp.int32)
        out = lora_delta(x, a, b, idx)
        assert float(jnp.abs(out).max()) == 0.0

    def test_matches_dense_reference(self):
        n, d, r, o, T = 4, 16, 4, 24, 11
        ks = jax.random.split(KEY, 3)
        x = jax.random.normal(ks[0], (T, d))
        a = jax.random.normal(ks[1], (n, d, r))
        a = a.at[0].set(0.0)
        b = jax.random.normal(ks[2], (n, r, o))
        idx = jax.random.randint(KEY, (T,), 0, n)
        got = lora_delta(x, a, b, idx)
        want = jnp.stack([(x[t] @ a[idx[t]]) @ b[idx[t]]
                          for t in range(T)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestActivationSemantics:
    def test_pre_activation_equals_base(self):
        """Hidden states BEFORE the activation point are bit-identical
        with and without the adapter — the paper's reuse precondition."""
        cfg, params, stacked = setup()
        B, S, inv = 2, 24, 16
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
        aidx = jnp.where(jnp.arange(S) >= inv, 1, 0)[None].repeat(B, 0)
        h_base, _, c_base = forward_full(params, cfg, toks,
                                         return_caches=True)
        h_al, _, c_al = forward_full(params, cfg, toks, adapters=stacked,
                                     adapter_idx=aidx, return_caches=True)
        # pre-activation K/V identical (bit-exact)
        k_b = np.asarray(c_base["seg0"]["k"])[..., :inv, :, :]
        k_a = np.asarray(c_al["seg0"]["k"])[..., :inv, :, :]
        np.testing.assert_array_equal(k_b, k_a)
        # post-activation K/V differ
        kb2 = np.asarray(c_base["seg0"]["k"])[..., inv:, :, :]
        ka2 = np.asarray(c_al["seg0"]["k"])[..., inv:, :, :]
        assert np.abs(kb2 - ka2).max() > 0

    def test_full_activation_equals_vanilla_lora(self):
        """adapter_idx=slot everywhere == classic LoRA forward."""
        cfg, params, stacked = setup()
        B, S = 2, 16
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
        all_on = jnp.ones((B, S), jnp.int32)
        h1, _, _ = forward_full(params, cfg, toks, adapters=stacked,
                                adapter_idx=all_on)
        # manual vanilla-LoRA: fold delta into an explicit qkv comparison
        # at layer level
        lp = jax.tree.map(lambda a: a[0, 0], params["blocks"]["seg0"])
        al = jax.tree.map(lambda a: a[0, 0], stacked["seg0"])
        x = jax.random.normal(KEY, (B, S, cfg.d_model),
                              jnp.float32).astype(h1.dtype)
        q1, k1, v1 = qkv_project(lp["attn"], cfg, x, al, all_on)
        # dense: W + A@B folded
        wq = lp["attn"]["wq"] + al["aq"][1] @ al["bq"][1]
        q2 = (x @ wq).reshape(B, S, cfg.num_heads, cfg.head_dim)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                                   rtol=2e-5, atol=2e-5)

    def test_mixed_batch_rows_independent(self):
        """Row 0 base, row 1 adapted: row 0 must match a pure-base run
        (the paper's heterogeneous batching)."""
        cfg, params, stacked = setup()
        S = 16
        toks = jax.random.randint(KEY, (2, S), 0, cfg.vocab_size)
        aidx = jnp.stack([jnp.zeros((S,), jnp.int32),
                          jnp.ones((S,), jnp.int32)])
        h_mix, _, _ = forward_full(params, cfg, toks, adapters=stacked,
                                   adapter_idx=aidx)
        h_base, _, _ = forward_full(params, cfg, toks)
        np.testing.assert_array_equal(np.asarray(h_mix[0]),
                                      np.asarray(h_base[0]))
        assert np.abs(np.asarray(h_mix[1]) -
                      np.asarray(h_base[1])).max() > 0


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-2.7b"])
def test_ssm_pre_activation_state_identical(arch):
    """Beyond-paper: the SSM recurrent state after pre-activation tokens
    is identical between base and adapter — the soundness condition for
    state-snapshot reuse."""
    cfg = get_reduced(arch)
    params = init_params(KEY, cfg)
    w = init_adapter_weights(jax.random.key(5), cfg, 8)
    stacked = stack_adapters(cfg, [w], 8)
    B, S, inv = 1, 32, 32        # fully pre-activation
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    aidx = jnp.where(jnp.arange(S) >= inv, 1, 0)[None]
    _, _, c_base = forward_full(params, cfg, toks, return_caches=True)
    _, _, c_al = forward_full(params, cfg, toks, adapters=stacked,
                              adapter_idx=aidx, return_caches=True)
    for seg in c_base:
        if "ssm" in c_base[seg]:
            np.testing.assert_array_equal(
                np.asarray(c_base[seg]["ssm"]),
                np.asarray(c_al[seg]["ssm"]))
