"""Base-aligned block hashing — unit + hypothesis property tests.

These encode the paper's §3 semantics (Fig. 3): which blocks are
interchangeable between the base model, aLoRA adapters, and vanilla
LoRA adapters.
"""
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.block_hash import (AdapterKey, block_extra, hash_block,
                                   request_block_hashes)

BS = 16


def toks(n, seed=0):
    return [(i * 7919 + seed) % 1000 for i in range(n)]


class TestBlockExtra:
    def test_base_model_no_extra(self):
        assert block_extra(None, 0, 16) == ()

    def test_vanilla_lora_always_salted(self):
        a = AdapterKey("ad", "lora")
        assert block_extra(a, 0, 16) == ("ad",)
        assert block_extra(a, 1000, 1016) == ("ad",)

    def test_alora_pre_activation_base_aligned(self):
        a = AdapterKey("ad", "alora", inv_start=50)
        assert block_extra(a, 0, 16) == ()          # entirely before
        assert block_extra(a, 32, 48) == ()
        assert block_extra(a, 48, 64) == ("ad",)    # straddles activation
        assert block_extra(a, 64, 80) == ("ad",)    # after

    def test_alora_boundary_exact(self):
        a = AdapterKey("ad", "alora", inv_start=48)
        assert block_extra(a, 32, 48) == ()
        assert block_extra(a, 48, 64) == ("ad",)


class TestRequestHashes:
    def test_partial_blocks_not_hashed(self):
        assert len(request_block_hashes(toks(47), BS)) == 2
        assert len(request_block_hashes(toks(48), BS)) == 3

    def test_alora_prefix_matches_base(self):
        t = toks(100)
        base = request_block_hashes(t, BS)
        al = request_block_hashes(t, BS, AdapterKey("a", "alora", 50))
        # blocks 0..2 end at 48 <= 50: base-aligned
        assert base[:3] == al[:3]
        assert all(b != a for b, a in zip(base[3:], al[3:]))

    def test_two_aloras_share_pre_activation(self):
        t = toks(100)
        a1 = request_block_hashes(t, BS, AdapterKey("a1", "alora", 64))
        a2 = request_block_hashes(t, BS, AdapterKey("a2", "alora", 64))
        assert a1[:4] == a2[:4]
        assert a1[4:] != a2[4:]

    def test_vanilla_lora_isolated(self):
        t = toks(100)
        base = request_block_hashes(t, BS)
        lo = request_block_hashes(t, BS, AdapterKey("a", "lora"))
        assert all(b != l for b, l in zip(base, lo))

    def test_salt_isolates(self):
        t = toks(64)
        assert request_block_hashes(t, BS) != \
            request_block_hashes(t, BS, salt=("img123",))

    def test_chaining_diverges_after_difference(self):
        t1, t2 = toks(64), toks(64)
        t2[20] += 1                        # differ inside block 1
        h1 = request_block_hashes(t1, BS)
        h2 = request_block_hashes(t2, BS)
        assert h1[0] == h2[0]
        assert h1[1] != h2[1]
        assert h1[2] != h2[2]              # chained: divergence persists


@given(st.lists(st.integers(0, 500), min_size=0, max_size=200),
       st.lists(st.integers(0, 500), min_size=0, max_size=200),
       st.sampled_from([4, 8, 16]))
@settings(max_examples=60, deadline=None)
def test_prop_hash_prefix_equality(t1, t2, bs):
    """hash[i] equal  ⇔  token prefixes up to block i+1 equal."""
    h1 = request_block_hashes(t1, bs)
    h2 = request_block_hashes(t2, bs)
    for i in range(min(len(h1), len(h2))):
        same_prefix = t1[:(i + 1) * bs] == t2[:(i + 1) * bs]
        assert (h1[i] == h2[i]) == same_prefix


@given(st.lists(st.integers(0, 500), min_size=1, max_size=150),
       st.integers(0, 160),
       st.sampled_from([4, 8, 16]))
@settings(max_examples=60, deadline=None)
def test_prop_alora_base_alignment(t, inv, bs):
    """aLoRA block hash equals the base hash exactly when the block ends
    at or before the activation point (the paper's reuse criterion)."""
    base = request_block_hashes(t, bs)
    al = request_block_hashes(t, bs, AdapterKey("x", "alora", inv))
    for i, (hb, ha) in enumerate(zip(base, al)):
        assert (hb == ha) == ((i + 1) * bs <= inv)
