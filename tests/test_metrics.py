"""Metrics-aggregation regressions (serving/metrics.py).

1. ``throughput_tok_per_s`` is tokens / MAKESPAN (max done − min
   arrival).  The old tokens / Σ per-request e2e double-counts
   overlapped wall-clock under concurrency and underreports throughput;
   that per-request service rate is preserved as ``tok_per_req_s``.
2. ``MetricsAggregate.row()`` on an empty aggregate returns NaNs
   (renderers show ``-``) instead of raising KeyError — a pipeline
   stage that saw no requests must not crash the benchmark report.
"""
import math

import numpy as np

from benchmarks.common import stage_row
from repro.serving.metrics import (METRIC_KEYS, RESERVOIR_MAX,
                                   MetricsAggregate, aggregate,
                                   fmt_speedups, merge_aggregates,
                                   speedup_table)


def fake_metrics(arrival, done, prompt_len=50, output_len=50):
    e2e = done - arrival
    base = {k: e2e / 4 for k in METRIC_KEYS}
    base.update(e2e=e2e, arrival=arrival, done=done,
                prompt_len=prompt_len, output_len=output_len,
                cache_hit_frac=0.0)
    return base


def test_throughput_uses_makespan_not_summed_e2e():
    """Two fully-overlapped requests, each 100 tokens over [0, 10]s: the
    system served 200 tokens in 10 wall-clock seconds (20 tok/s), not
    in 20 summed request-seconds (10 tok/s)."""
    m = aggregate([fake_metrics(0.0, 10.0), fake_metrics(0.0, 10.0)])
    assert m.throughput_tok_per_s == 200 / 10.0
    assert m.tok_per_req_s == 200 / 20.0        # the old value, renamed


def test_throughput_staggered_arrivals():
    """Makespan spans first arrival to last completion."""
    m = aggregate([fake_metrics(0.0, 10.0), fake_metrics(5.0, 20.0)])
    assert m.throughput_tok_per_s == 200 / 20.0
    assert m.tok_per_req_s == 200 / 25.0


def test_throughput_falls_back_without_endpoints():
    """Hand-built metric dicts without arrival/done keys keep the
    per-request rate rather than inventing a wall-clock."""
    recs = [fake_metrics(0.0, 10.0)]
    for r in recs:
        del r["arrival"], r["done"]
    m = aggregate(recs)
    assert m.throughput_tok_per_s == m.tok_per_req_s == 100 / 10.0


def test_empty_aggregate_row_returns_nans():
    """aggregate([]) used to return empty dicts that made row() raise
    KeyError on every METRIC_KEYS lookup; an empty pipeline stage now
    aggregates to NaNs."""
    m = aggregate([])
    assert m.n == 0
    row = m.row()
    assert set(row) == set(METRIC_KEYS)
    assert all(math.isnan(v) for v in row.values())


def test_empty_stage_renders_dashes():
    """The benchmark stage renderer shows '-' for an empty stage instead
    of crashing the report."""
    s = stage_row(aggregate([]))
    assert "queue=-" in s and "hit=-" in s
    # a non-empty aggregate still renders numbers
    s2 = stage_row(aggregate([fake_metrics(0.0, 10.0)]))
    assert "-" not in s2.replace("hit=0.00", "")


def test_speedup_table_empty_baseline_is_absent_not_inf():
    """An empty baseline has no stage means at all — that is 'stage
    absent' (NaN, rendered '-'), not an infinite speedup."""
    sp = speedup_table(aggregate([]), aggregate([fake_metrics(0.0, 1.0)]))
    assert set(sp)                               # keys present, no raise
    assert all(math.isnan(v) for v in sp.values())
    rendered = fmt_speedups(sp)
    assert "nan" not in rendered and "inf" not in rendered
    assert "e2e=-" in rendered


def test_speedup_table_true_zero_vs_absent():
    """inf is reserved for a measured zero in ours against a positive
    baseline; 0/0 is a 1.0 no-op; a missing key on either side is NaN."""
    b = MetricsAggregate(1, {"e2e": 2.0, "ttft": 0.0}, {}, {}, 0.0)
    o = MetricsAggregate(1, {"e2e": 0.0, "ttft": 0.0}, {}, {}, 0.0)
    sp = speedup_table(b, o, keys=("e2e", "ttft", "queue"))
    assert sp["e2e"] == float("inf")             # true zero, positive base
    assert sp["ttft"] == 1.0                     # 0/0 no-op
    assert math.isnan(sp["queue"])               # absent on both sides
    assert "queue=-" in fmt_speedups(sp)


def test_row_default_construction_keeps_field_order():
    """MetricsAggregate stays positionally constructible for existing
    callers (tok_per_req_s defaults)."""
    m = MetricsAggregate(0, {}, {}, {}, 0.0)
    assert m.tok_per_req_s == 0.0


# ---------------------------------------------------------------------------
# merge_aggregates — the multi-replica router's fleet roll-up
# ---------------------------------------------------------------------------
def test_merge_uses_union_makespan_not_summed_throughput():
    """Two replicas each serving 100 tokens over the SAME [0, 10]s
    window: the fleet did 200 tokens in 10 wall-clock seconds (20
    tok/s).  Summing per-replica throughputs would claim 20 as well
    here but double-counts as soon as windows overlap partially — the
    staggered case below is the discriminating one."""
    a = aggregate([fake_metrics(0.0, 10.0)])
    b = aggregate([fake_metrics(0.0, 10.0)])
    m = merge_aggregates([a, b])
    assert m.n == 2
    assert m.total_tokens == 200
    assert m.throughput_tok_per_s == 200 / 10.0


def test_merge_staggered_windows():
    """Replica windows [0,10] and [5,20]: union makespan is 20s, so the
    fleet rate is 200/20 = 10 tok/s — NOT the 100/10 + 100/15 ≈ 16.7
    a per-replica sum would report (the [5,10] overlap counted twice)."""
    a = aggregate([fake_metrics(0.0, 10.0)])
    b = aggregate([fake_metrics(5.0, 20.0)])
    m = merge_aggregates([a, b])
    assert m.throughput_tok_per_s == 200 / 20.0
    assert m.t_min_arrival == 0.0 and m.t_max_done == 20.0
    summed = a.throughput_tok_per_s + b.throughput_tok_per_s
    assert m.throughput_tok_per_s < summed


def test_merge_means_are_n_weighted():
    """Means merge exactly: 1 request at e2e=10 + 3 at e2e=2 → 4."""
    a = aggregate([fake_metrics(0.0, 10.0)])
    b = aggregate([fake_metrics(0.0, 2.0)] * 3)
    m = merge_aggregates([a, b])
    assert m.n == 4
    assert math.isclose(m.means["e2e"], (10.0 + 3 * 2.0) / 4)


def test_merge_single_and_empty_parts():
    """Empty parts drop out; a single surviving part passes through
    untouched (no approximation applied); all-empty merges to the empty
    aggregate."""
    a = aggregate([fake_metrics(0.0, 10.0)])
    assert merge_aggregates([a, aggregate([])]) is a
    m = merge_aggregates([aggregate([]), aggregate([])])
    assert m.n == 0 and m.throughput_tok_per_s == 0.0


def test_merge_percentiles_exact_from_reservoirs():
    """Parts with DIFFERENT distributions: the merged p50/p99 must be
    the percentile of the pooled per-request values, not the n-weighted
    mean of per-part percentiles (which is only right for identically
    distributed parts)."""
    a = aggregate([fake_metrics(0.0, d) for d in (1.0, 2.0, 3.0)])
    b = aggregate([fake_metrics(0.0, d) for d in (10.0, 20.0, 30.0,
                                                  40.0, 50.0)])
    m = merge_aggregates([a, b])
    pooled = np.array([1.0, 2.0, 3.0, 10.0, 20.0, 30.0, 40.0, 50.0])
    assert math.isclose(m.p50["e2e"], float(np.percentile(pooled, 50)))
    assert math.isclose(m.p99["e2e"], float(np.percentile(pooled, 99)))
    # the old approximation would have reported something else
    approx = (a.p50["e2e"] * a.n + b.p50["e2e"] * b.n) / (a.n + b.n)
    assert not math.isclose(m.p50["e2e"], approx)
    # the merged aggregate still fits the reservoir → chained merges
    # stay exact as well
    assert m.samples is not None and len(m.samples["e2e"]) == m.n
    c = aggregate([fake_metrics(0.0, 100.0)])
    m2 = merge_aggregates([m, c])
    pooled2 = np.append(pooled, 100.0)
    assert math.isclose(m2.p50["e2e"], float(np.percentile(pooled2, 50)))


def test_merge_percentiles_fall_back_without_samples():
    """A part that reduced away its raw values (hand-built aggregate,
    samples=None) downgrades the merge to the n-weighted approximation
    instead of crashing or silently pretending exactness."""
    a = aggregate([fake_metrics(0.0, 2.0)] * 2)
    b = MetricsAggregate(
        2, dict.fromkeys(METRIC_KEYS, 1.0), dict.fromkeys(METRIC_KEYS, 1.0),
        dict.fromkeys(METRIC_KEYS, 1.0), 0.0, total_tokens=100,
        total_e2e=2.0)
    m = merge_aggregates([a, b])
    assert math.isclose(m.p50["e2e"], (a.p50["e2e"] * 2 + 1.0 * 2) / 4)
    assert m.samples is None                     # inexact → no reservoir


def test_reservoir_is_bounded():
    """aggregate() never stores more than RESERVOIR_MAX raw values per
    metric; an over-full part makes merges fall back (len < n)."""
    recs = [fake_metrics(0.0, 1.0)] * (RESERVOIR_MAX + 5)
    a = aggregate(recs)
    assert len(a.samples["e2e"]) == RESERVOIR_MAX < a.n


def test_merge_without_endpoints_falls_back():
    """Parts whose sources carried no arrival/done timestamps (NaN
    endpoints) can't form a union makespan — the merge falls back to
    the per-request rate instead of inventing a wall-clock."""
    recs = [fake_metrics(0.0, 10.0)]
    for r in recs:
        del r["arrival"], r["done"]
    a, b = aggregate(recs), aggregate([fake_metrics(0.0, 5.0)])
    m = merge_aggregates([a, b])
    assert m.throughput_tok_per_s == m.tok_per_req_s
    assert m.total_tokens == a.total_tokens + b.total_tokens
