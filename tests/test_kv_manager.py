"""Paged block manager semantics (vLLM-style ref-count + lazy eviction)."""
import pytest

from repro.core.block_hash import hash_block
from repro.core.kv_manager import BlockManager, OutOfBlocks


def h(i):
    return hash_block(None, [i])


def test_allocate_release_cycle():
    m = BlockManager(4, 16)
    bids = [m.allocate() for _ in range(4)]
    assert m.num_free() == 0
    with pytest.raises(OutOfBlocks):
        m.allocate()
    m.release_all(bids)
    assert m.num_free() == 4


def test_freed_block_revivable_until_evicted():
    m = BlockManager(2, 16)
    b = m.allocate()
    m.register(b, h(1))
    m.release(b)
    # still in index though free
    assert m.lookup(h(1)) == b
    got = m.acquire_cached(h(1))
    assert got == b
    m.release(b)
    # allocating both blocks evicts LRU entries
    b2 = m.allocate()
    b3 = m.allocate()
    assert m.lookup(h(1)) is None          # evicted
    assert m.evictions >= 1


def test_lru_eviction_order():
    m = BlockManager(3, 16)
    bs = [m.allocate() for _ in range(3)]
    for i, b in enumerate(bs):
        m.register(b, h(i))
    m.release(bs[1])                       # freed first -> evicted first
    m.release(bs[0])
    m.release(bs[2])
    m.allocate()
    assert m.lookup(h(1)) is None
    assert m.lookup(h(0)) is not None


def test_refcount_sharing():
    m = BlockManager(2, 16)
    b = m.allocate()
    m.register(b, h(5))
    m.release(b)
    a1 = m.acquire_cached(h(5))
    a2 = m.acquire_cached(h(5))
    assert a1 == a2 == b
    m.release(b)
    assert m.num_free() == 1               # still held once
    m.release(b)
    assert m.num_free() == 2


def test_register_dedup():
    m = BlockManager(4, 16)
    b1, b2 = m.allocate(), m.allocate()
    assert m.register(b1, h(7)) == b1
    assert m.register(b2, h(7)) == b1      # canonical id kept


def test_hit_rate_accounting():
    m = BlockManager(4, 16)
    assert m.acquire_cached(h(1)) is None
    b = m.allocate()
    m.register(b, h(1))
    assert m.acquire_cached(h(1)) == b
    assert m.hits == 1 and m.misses == 1
    assert m.hit_rate() == 0.5
