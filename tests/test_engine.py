"""Serving-engine integration tests — the paper's system end-to-end.

The decisive invariants:
 1. engine generation == dense-path reference (paged cache correctness);
 2. aLoRA WITH cross-model reuse == aLoRA from scratch (reuse exactness);
 3. aLoRA reuses base blocks, vanilla LoRA reuses none (paper Fig. 3);
 4. generated (decode) blocks are cached too (paper §4.4);
 5. SSM/hybrid state-snapshot reuse is exact (beyond-paper);
 6. chunked prefill, continuous batching, eviction under pressure.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.alora import AdapterSpec, init_adapter_weights
from repro.models import (decode_step, forward_full, init_params,
                          logits_for)
from repro.models.model import prefill_to_decode_caches
from repro.serving import Engine, EngineConfig
from repro.serving import pipelines as P

KEY = jax.random.key(0)
INV = (7, 8, 9)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced("granite-3.2-8b")
    params = init_params(KEY, cfg)
    w = init_adapter_weights(jax.random.key(7), cfg, 8)
    return cfg, params, w


def mk_engine(cfg, params, w, kind="alora", **ecfg_kw):
    spec = AdapterSpec("uq", rank=8,
                       invocation_tokens=INV if kind == "alora" else None)
    return Engine(cfg, params, adapters=[(spec, w)],
                  engine_cfg=EngineConfig(**ecfg_kw))


def prompt_of(n, seed=0, vocab=500):
    return list(np.random.RandomState(seed).randint(10, vocab, n))


class TestEngineCorrectness:
    def test_engine_matches_dense_reference(self, dense_setup):
        cfg, params, w = dense_setup
        prompt = prompt_of(50)
        h, _, pc = forward_full(params, cfg, jnp.asarray([prompt]),
                                return_caches=True)
        lg = logits_for(params, cfg, h)[0, -1]
        dc = prefill_to_decode_caches(cfg, pc, len(prompt), 256)
        ref = [int(jnp.argmax(lg))]
        pos = len(prompt)
        for _ in range(7):
            lg2, dc = decode_step(params, cfg,
                                  jnp.asarray([[ref[-1]]]), dc, pos)
            ref.append(int(jnp.argmax(lg2[0, 0])))
            pos += 1
        eng = mk_engine(cfg, params, w)
        rid = eng.submit(prompt, 8)
        eng.run_until_idle()
        assert eng.request(rid).output_tokens == ref

    def test_chunked_prefill_equivalence(self, dense_setup):
        """Tiny chunk budget (multiple chunks per prompt) must not change
        outputs."""
        cfg, params, w = dense_setup
        prompt = prompt_of(90, seed=3)
        outs = []
        for budget in (256, 32):
            eng = mk_engine(cfg, params, w,
                            max_batched_tokens=budget)
            rid = eng.submit(prompt, 6)
            eng.run_until_idle()
            outs.append(eng.request(rid).output_tokens)
        assert outs[0] == outs[1]

    def test_continuous_batching_matches_solo(self, dense_setup):
        """Three concurrent requests must produce the same outputs as
        each run alone (batch isolation)."""
        cfg, params, w = dense_setup
        prompts = [prompt_of(40 + 7 * i, seed=i) for i in range(3)]
        solo = []
        for p in prompts:
            eng = mk_engine(cfg, params, w, enable_prefix_cache=False)
            rid = eng.submit(p, 5)
            eng.run_until_idle()
            solo.append(eng.request(rid).output_tokens)
        eng = mk_engine(cfg, params, w, enable_prefix_cache=False)
        rids = [eng.submit(p, 5) for p in prompts]
        eng.run_until_idle()
        multi = [eng.request(r).output_tokens for r in rids]
        assert multi == solo


class TestCrossModelReuse:
    def run_pipeline(self, cfg, params, w, kind, enable_cache=True):
        eng = mk_engine(cfg, params, w, kind,
                        enable_prefix_cache=enable_cache)
        x = prompt_of(100, seed=1, vocab=cfg.vocab_size)
        r1 = eng.submit(x, 12)
        eng.run_until_idle()
        y = eng.request(r1).output_tokens
        p2 = x + y + list(INV)
        r2 = eng.submit(p2, 6, adapter_name="uq")
        eng.run_until_idle()
        return eng.request(r2)

    def test_alora_reuses_base_blocks(self, dense_setup):
        cfg, params, w = dense_setup
        req = self.run_pipeline(cfg, params, w, "alora")
        assert req.n_cache_hit_tokens > 0
        # reuse = full blocks that are BOTH pre-activation and actually
        # cached by the base run (the base computes KV for prompt+gen-1
        # tokens: the last sampled token's KV is never computed)
        bs = 16
        n_base_kv = req.inv_start - 1        # prompt2 = x + y + INV
        expect = (min(req.inv_start, n_base_kv) // bs) * bs
        assert req.n_cache_hit_tokens == expect

    def test_vanilla_lora_no_reuse(self, dense_setup):
        cfg, params, w = dense_setup
        req = self.run_pipeline(cfg, params, w, "lora")
        assert req.n_cache_hit_tokens == 0

    def test_reuse_is_exact(self, dense_setup):
        """The headline invariant: cached-reuse outputs == from-scratch."""
        cfg, params, w = dense_setup
        with_cache = self.run_pipeline(cfg, params, w, "alora", True)
        scratch = self.run_pipeline(cfg, params, w, "alora", False)
        assert with_cache.output_tokens == scratch.output_tokens

    def test_generated_blocks_cached(self, dense_setup):
        """Decode-produced blocks register in the prefix cache: a second
        request over (x + y) hits blocks that only existed as generated
        tokens (paper §4.4)."""
        cfg, params, w = dense_setup
        eng = mk_engine(cfg, params, w)
        x = prompt_of(48, seed=2)
        r1 = eng.submit(x, 32)
        eng.run_until_idle()
        y = eng.request(r1).output_tokens
        r2 = eng.submit(x + y, 4)        # base again over full history
        eng.run_until_idle()
        req2 = eng.request(r2)
        assert req2.n_cache_hit_tokens > len(x)

    def test_adapter_base_two_way(self, dense_setup):
        """Adapter prefills first; base reuses its pre-activation blocks
        (paper App. C)."""
        cfg, params, w = dense_setup
        eng = mk_engine(cfg, params, w)
        x = prompt_of(80, seed=5)
        r1 = eng.submit(x + list(INV), 4, adapter_name="uq")
        eng.run_until_idle()
        r2 = eng.submit(x, 4)            # base over the same x
        eng.run_until_idle()
        assert eng.request(r2).n_cache_hit_tokens >= 64


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-2.7b"])
def test_ssm_state_reuse_exact(arch):
    cfg = get_reduced(arch)
    params = init_params(KEY, cfg)
    w = init_adapter_weights(jax.random.key(7), cfg, 8)
    outs, hits = [], []
    for cache_on in (True, False):
        eng = mk_engine(cfg, params, w, "alora",
                        enable_prefix_cache=cache_on)
        x = prompt_of(96, seed=1, vocab=cfg.vocab_size)
        r1 = eng.submit(x, 8)
        eng.run_until_idle()
        y = eng.request(r1).output_tokens
        r2 = eng.submit(x + y + list(INV), 4, adapter_name="uq")
        eng.run_until_idle()
        req = eng.request(r2)
        outs.append(req.output_tokens)
        hits.append((req.n_cache_hit_tokens, req.state_reused))
    assert outs[0] == outs[1]
    assert hits[0][0] > 0 and hits[0][1]
    assert hits[1] == (0, False)


def test_eviction_under_pressure(dense_setup):
    """Pool smaller than the working set: engine still completes all
    requests; stats show evictions."""
    cfg, params, w = dense_setup
    eng = mk_engine(cfg, params, w, num_blocks=12, max_running=2)
    rids = [eng.submit(prompt_of(64, seed=i), 4) for i in range(4)]
    eng.run_until_idle()
    for r in rids:
        assert len(eng.request(r).output_tokens) == 4
    assert eng.kv_mgr.evictions > 0


def test_async_poisson_pipeline(dense_setup):
    cfg, params, w = dense_setup
    eng = mk_engine(cfg, params, w)
    res = P.async_base_adapter(eng, adapter_name="uq", arrival_rate=5.0,
                               num_requests=4, prompt_len=32,
                               gen_len=8, eval_len=4)
    m = res.stage_metrics(eng, "eval")
    assert m.n == 4
    assert m.means["e2e"] > 0
    assert m.means["cache_hit_frac"] > 0.3


def test_multi_adapter_parallel(dense_setup):
    """Five adapters invoked in parallel on the same context (§4.4.1)."""
    cfg, params, _ = dense_setup
    adapters = []
    for i in range(5):
        spec = AdapterSpec(f"a{i}", rank=8,
                           invocation_tokens=(7 + i, 8, 9))
        adapters.append((spec,
                         init_adapter_weights(jax.random.key(i), cfg, 8)))
    eng = Engine(cfg, params, adapters=adapters)
    res = P.base_adapter(eng, adapter_names=[f"a{i}" for i in range(5)],
                         prompt_len=48, gen_len=8, eval_len=4,
                         feed_back_to_base=True)
    m = res.stage_metrics(eng, "eval")
    assert m.n == 5
    assert m.means["cache_hit_frac"] > 0.5
    assert len(res.final_ids) == 1
