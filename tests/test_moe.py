"""MoE routing + the two dispatch implementations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.moe import init_moe, moe_masked_dense, route

KEY = jax.random.key(0)
CFG = get_reduced("granite-moe-1b-a400m")
P = init_moe(KEY, CFG, jnp.float32)


def test_router_topk_weights_normalized():
    x = jax.random.normal(KEY, (3, 8, CFG.d_model))
    w, idx, aux = route(P, CFG, x)
    k = CFG.moe.experts_per_token
    assert w.shape == (3, 8, k) and idx.shape == (3, 8, k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert float(aux) > 0


def test_masked_dense_matches_per_token_reference():
    x = jax.random.normal(jax.random.key(1), (1, 6, CFG.d_model))
    y, _ = moe_masked_dense(P, CFG, x)
    w, idx, _ = route(P, CFG, x)
    # reference: per-token loop over its experts
    d = CFG.d_model
    want = np.zeros((1, 6, d), np.float32)
    for t in range(6):
        for j in range(CFG.moe.experts_per_token):
            e = int(idx[0, t, j])
            xe = x[0, t]
            h = jax.nn.silu(xe @ P["w_gate"][e]) * (xe @ P["w_up"][e])
            want[0, t] += float(w[0, t, j]) * np.asarray(h @ P["w_down"][e])
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-4, atol=1e-4)


def test_expert_parallel_matches_masked_dense_1dev():
    """On a 1-device mesh with generous capacity the expert-parallel
    shard_map path must agree with the dense reference (no drops)."""
    from repro.launch.mesh import make_host_mesh
    from repro.models.moe import moe_expert_parallel
    mesh = make_host_mesh()
    x = jax.random.normal(jax.random.key(2), (2, 8, CFG.d_model))
    y_ref, _ = moe_masked_dense(P, CFG, x)
    y_ep, _ = moe_expert_parallel(P, CFG, x, mesh=mesh,
                                  batch_axes=("data",),
                                  model_axis="model",
                                  capacity_factor=32.0)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_expert_parallel_drops_on_overflow():
    """With capacity 0+ the output shrinks (tokens dropped), proving the
    capacity mechanism engages rather than silently growing buffers."""
    from repro.launch.mesh import make_host_mesh
    from repro.models.moe import moe_expert_parallel
    mesh = make_host_mesh()
    x = jax.random.normal(jax.random.key(2), (2, 8, CFG.d_model))
    y_full, _ = moe_expert_parallel(P, CFG, x, mesh=mesh,
                                    batch_axes=("data",),
                                    model_axis="model",
                                    capacity_factor=32.0)
    y_tight, _ = moe_expert_parallel(P, CFG, x, mesh=mesh,
                                     batch_axes=("data",),
                                     model_axis="model",
                                     capacity_factor=0.05)
    assert float(jnp.abs(y_tight).sum()) < float(jnp.abs(y_full).sum())
