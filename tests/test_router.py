"""Multi-replica router (serving/router.py): placement, stickiness,
drain/failover and fleet-vs-oracle equivalence.

The router is pure host-side python over the replica-facing Engine
surface, so everything here runs single-device — no mesh marker.  The
load-bearing property is the oracle equivalence: because decoding is
deterministic argmax over shared params, an R-replica affinity fleet
must produce token-for-token the same outputs as a single engine fed
the same trace, regardless of how placement scatters the requests.
Locality scoring then only changes WHERE prefixes hit, never WHAT gets
sampled — which is what makes the hit-rate benchmark
(``benchmarks/bench_router.py``) a pure placement measurement.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.alora import AdapterSpec, init_adapter_weights
from repro.models import init_params
from repro.serving import Engine, EngineConfig
from repro.serving.router import Router

KEY = jax.random.key(0)
INV = (7, 8, 9)


def scaled_adapter(cfg, seed, rank=8, scale=30.0):
    w = init_adapter_weights(jax.random.key(seed), cfg, rank)
    return {seg: {k: (v * scale if k.startswith("b") else v)
                  for k, v in leaves.items()}
            for seg, leaves in w.items()}


@pytest.fixture(scope="module")
def zoo():
    """Lazily-built (cfg, params, adapters) per arch, shared across the
    module so each family compiles once."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_reduced(arch)
            params = init_params(KEY, cfg)
            ads = [(AdapterSpec(f"ad{i}", rank=8,
                                invocation_tokens=INV if i % 2 else None),
                    scaled_adapter(cfg, 100 + i))
                   for i in range(3)]
            cache[arch] = (cfg, params, ads)
        return cache[arch]

    return get


def mk_router(zoo, arch, n, policy="affinity", **ecfg_kw):
    """N identically-constructed single-device replicas behind a router.

    Same construction per replica (shared cached params, same adapter
    registration order) — the registry uids that salt block hashes must
    agree across the fleet for prefix chains to be portable.
    """
    cfg, params, ads = zoo(arch)
    kw = dict(max_running=4, max_batched_tokens=64, adapter_slots=2)
    kw.update(ecfg_kw)
    return Router([Engine(cfg, params, adapters=ads,
                          engine_cfg=EngineConfig(**kw))
                   for _ in range(n)], policy=policy)


def run_trace(router, cfg, *, sessions=5, turns=2, gen=5, seed=3,
              use_sessions=False):
    """Multi-turn multi-adapter trace (the bench_router shape, smaller):
    turn k+1 extends turn k's prompt + generated tokens, alternating
    base and aLoRA turns.  Returns router-global ids in submit order."""
    rng = np.random.RandomState(seed)
    hi = min(400, cfg.vocab_size)
    convo = [list(rng.randint(10, hi, 24 + 4 * (s % 3)))
             for s in range(sessions)]
    gids = []
    for t in range(turns):
        round_ids = []
        for s in range(sessions):
            adapter = f"ad{s % 2}" if t % 2 else None
            kw = dict(session=s) if use_sessions else {}
            round_ids.append(router.submit(convo[s], gen,
                                           adapter_name=adapter, **kw))
        router.run_until_idle()
        for s, gid in enumerate(round_ids):
            out = router.request(gid).output_tokens
            assert len(out) == gen
            convo[s] = convo[s] + list(out) \
                + list(rng.randint(10, hi, 12))
        gids.extend(round_ids)
    return gids


# ---------------------------------------------------------------------------
# construction / validation
# ---------------------------------------------------------------------------
def test_construction_validation(zoo):
    with pytest.raises(ValueError):
        Router([])
    cfg, params, ads = zoo("granite-3.2-8b")
    eng = Engine(cfg, params, adapters=ads,
                 engine_cfg=EngineConfig(max_running=4,
                                         max_batched_tokens=64,
                                         adapter_slots=2))
    with pytest.raises(ValueError):
        Router([eng], policy="sticky-dice")


# ---------------------------------------------------------------------------
# fleet ≡ single-engine oracle (token for token)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,n", [("granite-3.2-8b", 2),
                                    ("granite-3.2-8b", 4),
                                    ("mamba2-2.7b", 2)])
def test_router_matches_single_engine_oracle(zoo, arch, n):
    """R-replica affinity fleet ≡ R=1 (a bare engine behind the router)
    on the same multi-turn trace: identical tokens for every global id.
    Placement may scatter requests — sampling must not notice."""
    cfg, _, _ = zoo(arch)
    oracle = mk_router(zoo, arch, 1)
    fleet = mk_router(zoo, arch, n)
    gids_o = run_trace(oracle, cfg)
    gids_f = run_trace(fleet, cfg)
    assert gids_o == gids_f
    for gid in gids_f:
        assert fleet.request(gid).output_tokens == \
            oracle.request(gid).output_tokens, gid
    # the fleet actually used more than one replica
    assert len({p.replica for p in fleet.placements}) > 1


# ---------------------------------------------------------------------------
# placement: locality scoring + spread
# ---------------------------------------------------------------------------
def test_affinity_follows_prefix_and_spreads_cold(zoo):
    """Two cold sessions spread (least-outstanding); each session's
    second turn follows its prefix blocks to the replica that served
    turn one, with a non-zero scored cache depth."""
    cfg, _, _ = zoo("granite-3.2-8b")
    router = mk_router(zoo, "granite-3.2-8b", 2)
    rng = np.random.RandomState(11)
    a = list(rng.randint(10, 400, 40))
    b = list(rng.randint(10, 400, 40))
    ga = router.submit(a, 5)
    gb = router.submit(b, 5)          # a's replica has outstanding work
    assert router.placements[0].replica != router.placements[1].replica
    router.run_until_idle()
    a2 = a + list(router.request(ga).output_tokens) + [17, 18, 19, 20]
    b2 = b + list(router.request(gb).output_tokens) + [21, 22, 23, 24]
    router.submit(a2, 5, adapter_name="ad1")   # aLoRA turn: base-aligned
    router.submit(b2, 5, adapter_name="ad1")   # hashes still match
    router.run_until_idle()
    for first, second in ((0, 2), (1, 3)):
        p1, p2 = router.placements[first], router.placements[second]
        assert p2.replica == p1.replica, (p1, p2)
        assert p2.cached_tokens > 0
        assert not p2.via_session


def test_sticky_sessions_pin(zoo):
    """``session=`` pins every later turn to the first turn's replica
    and the placement log records the pin."""
    cfg, _, _ = zoo("granite-3.2-8b")
    router = mk_router(zoo, "granite-3.2-8b", 2)
    run_trace(router, cfg, sessions=4, turns=2, use_sessions=True)
    by_session = {}
    for t in range(2):
        for s in range(4):
            p = router.placements[t * 4 + s]
            by_session.setdefault(s, []).append(p)
    for s, places in by_session.items():
        assert len({p.replica for p in places}) == 1, s
        assert not places[0].via_session        # first turn is scored
        assert all(p.via_session for p in places[1:]), s


def test_round_robin_is_blind(zoo):
    """round_robin cycles the live replicas in submit order, ignoring
    locality entirely (the bench baseline)."""
    router = mk_router(zoo, "granite-3.2-8b", 2, policy="round_robin")
    prompt = list(np.random.RandomState(4).randint(10, 400, 30))
    for _ in range(4):
        router.submit(list(prompt), 4)          # identical prompts...
    assert [p.replica for p in router.placements] == [0, 1, 0, 1]
    assert all(p.cached_tokens == 0 for p in router.placements)


# ---------------------------------------------------------------------------
# drain / failover
# ---------------------------------------------------------------------------
def test_drain_failover_loses_nothing(zoo):
    """Stopping a replica mid-flight re-routes its queued requests and
    drains its admitted ones: every request still reaches full length
    under its stable global id, and no new work lands on the stopped
    replica."""
    cfg, _, _ = zoo("granite-3.2-8b")
    router = mk_router(zoo, "granite-3.2-8b", 2)
    rng = np.random.RandomState(9)
    gen = 5
    gids = [router.submit(list(rng.randint(10, 400, 32 + i)), gen,
                          adapter_name=[None, "ad0", "ad1"][i % 3])
            for i in range(12)]
    for _ in range(2):                  # admit a first wave everywhere
        router.step()
    victim = 0
    assert any(r == victim for r, _ in router._routes.values())
    moved = router.stop_replica(victim)
    assert moved > 0 and router.reroutes == moved
    # idempotent; and the survivor cannot be stopped too
    assert router.stop_replica(victim) == 0
    with pytest.raises(RuntimeError):
        router.stop_replica(1)
    # the failed stop left the fleet routable
    extra = router.submit(list(rng.randint(10, 400, 30)), gen)
    assert router.replica_of(extra) == 1
    router.run_until_idle()
    for gid in gids + [extra]:
        assert len(router.request(gid).output_tokens) == gen, gid
    # drained replica finished its admitted work and holds nothing new
    assert router.replicas[victim].idle


def test_stop_replica_drops_unclaimed_stages(zoo):
    """Regression: a stopped replica only steps until its admitted work
    drains, so its pool's TTL expiry (tick) may never run again —
    unclaimed staging-tier prefetches, e.g. for requests just re-routed
    away, must be dropped at stop time, not pinned for the process
    lifetime."""
    router = mk_router(zoo, "granite-3.2-8b", 2)
    victim = 0
    pool = router.replicas[victim].adapter_pool
    uid = next(iter(pool._by_uid))
    assert pool.prefetch(uid)
    assert pool.staged_now == 1
    router.stop_replica(victim)
    assert pool.staged_now == 0
    assert pool.get(uid).device_layers is None
    # survivor's stages are untouched
    assert router.replicas[1].adapter_pool.staged_now == 0


def test_drain_rerouted_tokens_match_oracle(zoo):
    """Rerouted requests re-prefill from scratch on the survivor —
    deterministic decoding means their tokens still match an untouched
    single-engine run of the same trace."""
    cfg, _, _ = zoo("granite-3.2-8b")
    oracle = mk_router(zoo, "granite-3.2-8b", 1)
    fleet = mk_router(zoo, "granite-3.2-8b", 2)
    rng = np.random.RandomState(21)
    prompts = [list(rng.randint(10, 400, 30 + 2 * i)) for i in range(8)]
    go = [oracle.submit(list(p), 5) for p in prompts]
    oracle.run_until_idle()
    gf = [fleet.submit(list(p), 5) for p in prompts]
    fleet.step()
    fleet.stop_replica(1)
    fleet.run_until_idle()
    for a, b in zip(go, gf):
        assert oracle.request(a).output_tokens == \
            fleet.request(b).output_tokens


# ---------------------------------------------------------------------------
# fleet adapter lifecycle / stats
# ---------------------------------------------------------------------------
def test_fleet_adapter_registration_and_residency(zoo):
    cfg, params, ads = zoo("granite-3.2-8b")
    router = mk_router(zoo, "granite-3.2-8b", 2)
    uid = router.register_adapter(AdapterSpec("late", rank=8),
                                  scaled_adapter(cfg, 321))
    assert isinstance(uid, str) or isinstance(uid, int)
    gid = router.submit(list(range(10, 40)), 4, adapter_name="late")
    router.run_until_idle()
    assert len(router.request(gid).output_tokens) == 4
    idx = router.replica_of(gid)
    res = router.replicas[idx].adapter_residency()
    assert res.get("late") is True
    # the other replica registered it too (uid-aligned), just not resident
    other = router.replicas[1 - idx].adapter_residency()
    assert "late" in other and other["late"] is False
    router.unregister_adapter("late")
    assert all("late" not in eng.adapter_residency()
               for eng in router.replicas)


def test_probe_is_non_acquiring(zoo):
    """``cached_prefix_tokens`` is the router's placement primitive — it
    must not bump hit/miss counters or refcounts (a probed-but-not-
    placed replica would otherwise mis-report its cache behavior)."""
    cfg, _, _ = zoo("granite-3.2-8b")
    router = mk_router(zoo, "granite-3.2-8b", 1)
    prompt = list(np.random.RandomState(6).randint(10, 400, 40))
    router.submit(list(prompt), 5)
    router.run_until_idle()
    eng = router.replicas[0]
    mgr = eng.kv_mgr or eng.st_mgr
    h0, m0 = mgr.hits, mgr.misses
    depth = eng.cached_prefix_tokens(prompt + [1, 2, 3], "ad1")
    assert depth > 0
    assert (mgr.hits, mgr.misses) == (h0, m0)


def test_fleet_metrics_merge(zoo):
    """Fleet aggregate = merged per-replica parts: request counts and
    token totals sum exactly, throughput uses the union makespan (so it
    never exceeds what summing per-replica rates would claim)."""
    cfg, _, _ = zoo("granite-3.2-8b")
    router = mk_router(zoo, "granite-3.2-8b", 2)
    gids = run_trace(router, cfg, sessions=5, turns=2)
    fleet = router.metrics_for(gids)
    per = router.per_replica_metrics(gids)
    assert len(per) == 2                # affinity actually used both
    assert fleet.n == sum(p.n for p in per.values()) == len(gids)
    assert fleet.total_tokens == sum(p.total_tokens for p in per.values())
    assert 0 < fleet.throughput_tok_per_s <= \
        sum(p.throughput_tok_per_s for p in per.values())
    hit = router.kv_hit_rate()
    hits = sum((e.kv_mgr or e.st_mgr).hits for e in router.replicas)
    total = sum((e.kv_mgr or e.st_mgr).hits + (e.kv_mgr or e.st_mgr).misses
                for e in router.replicas)
    assert hit == hits / total
    assert 0.0 < hit < 1.0              # multi-turn trace actually reuses
