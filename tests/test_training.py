"""Training substrate: optimizer math, schedule, loss, checkpoints."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import forward_full, init_params
from repro.models.model import Runtime
from repro.training import (AdamWConfig, DataConfig, SyntheticDataset,
                            adamw_update, chunked_ce_loss, init_adamw,
                            init_train_state, lr_at, make_train_step,
                            restore_checkpoint, save_checkpoint)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr_at(cfg, 100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr_at(cfg, 55)) > float(lr_at(cfg, 100))


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_adamw(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_caps_update():
    cfg = AdamWConfig(lr=1.0, warmup_steps=0, grad_clip=1.0,
                      weight_decay=0.0)
    params = {"w": jnp.zeros((3,))}
    state = init_adamw(params)
    _, _, stats = adamw_update(cfg, {"w": jnp.full((3,), 1e6)}, state,
                               params)
    assert float(stats["grad_norm"]) > 1e5     # raw norm reported


def test_loss_decreases_on_repeated_batch():
    cfg = get_reduced("granite-3.2-8b")
    params = init_params(jax.random.key(0), cfg)
    state = init_train_state(params)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30),
        Runtime(), loss_chunk=16))
    ds = SyntheticDataset(DataConfig(vocab_size=cfg.vocab_size,
                                     seq_len=32, global_batch=4))
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    losses = []
    for _ in range(15):
        state, stats = step(state, batch)
        losses.append(float(stats["loss"]))
    assert losses[-1] < losses[0] - 0.5        # memorizes one batch


def test_chunked_loss_matches_full():
    cfg = get_reduced("granite-3.2-8b")
    params = init_params(jax.random.key(0), cfg)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0,
                              cfg.vocab_size)
    labels = jax.random.randint(jax.random.key(2), (B, S), 0,
                                cfg.vocab_size)
    mask = jnp.ones((B, S), jnp.float32)
    h, _, _ = forward_full(params, cfg, toks)
    l1 = chunked_ce_loss(params, cfg, h, labels, mask, chunk=8)
    l2 = chunked_ce_loss(params, cfg, h, labels, mask, chunk=32)
    assert float(jnp.abs(l1 - l2)) < 1e-4


def test_synthetic_data_deterministic():
    ds = SyntheticDataset(DataConfig(vocab_size=100, seq_len=16,
                                     global_batch=2, seed=3))
    b1, b2 = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_reduced("zamba2-2.7b")
    params = init_params(jax.random.key(0), cfg)
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params, step=7)
    like = jax.tree.map(lambda a: jnp.zeros_like(a), params)
    restored = restore_checkpoint(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "c.npz")
    save_checkpoint(path, {"w": jnp.zeros((3,))})
    with pytest.raises(AssertionError):
        restore_checkpoint(path, {"w": jnp.zeros((4,))})
