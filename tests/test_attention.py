"""Blocked (flash-style) attention vs naive reference + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.models.attention import (decode_attention, flash_attention,
                                    write_kv_cache)

KEY = jax.random.key(0)


def naive_attention(q, k, v, causal=True, window=0, q_offset=0):
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, k).astype(jnp.float32)
    s = s / (hd ** 0.5)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


@pytest.mark.parametrize("S,H,KV,hd,qb,kb", [
    (64, 4, 2, 16, 16, 16),
    (100, 8, 8, 8, 32, 16),     # non-divisible S -> padding
    (32, 6, 2, 8, 8, 8),        # GQA 3:1
])
def test_flash_matches_naive(S, H, KV, hd, qb, kb):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (2, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (2, S, KV, hd), jnp.float32)
    got = flash_attention(q, k, v, q_block=qb, kv_block=kb)
    want = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_window():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 8))
    k = jax.random.normal(ks[1], (1, 64, 4, 8))
    v = jax.random.normal(ks[2], (1, 64, 4, 8))
    got = flash_attention(q, k, v, window=16, q_block=16, kv_block=16)
    want = naive_attention(q, k, v, window=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_q_offset_chunked_equals_full():
    """Chunked prefill: processing the second half with q_offset against
    full K/V equals the tail of the full pass."""
    ks = jax.random.split(KEY, 3)
    S = 64
    q = jax.random.normal(ks[0], (1, S, 4, 8))
    k = jax.random.normal(ks[1], (1, S, 2, 8))
    v = jax.random.normal(ks[2], (1, S, 2, 8))
    full = flash_attention(q, k, v, q_block=16, kv_block=16)
    tail = flash_attention(q[:, 32:], k, v, q_offset=32, q_block=16,
                           kv_block=16)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, 32:]),
                               rtol=2e-5, atol=2e-5)


def test_skip_masked_blocks_equivalence():
    """§Perf triangular schedule must be numerically identical."""
    ks = jax.random.split(KEY, 3)
    S = 128
    q = jax.random.normal(ks[0], (1, S, 4, 16))
    k = jax.random.normal(ks[1], (1, S, 2, 16))
    v = jax.random.normal(ks[2], (1, S, 2, 16))
    base = flash_attention(q, k, v, q_block=32, kv_block=32,
                           skip_masked_blocks=False)
    skip = flash_attention(q, k, v, q_block=32, kv_block=32,
                           skip_masked_blocks=True)
    np.testing.assert_allclose(np.asarray(skip), np.asarray(base),
                               rtol=2e-5, atol=2e-5)


def test_non_causal_encoder_mode():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 48, 4, 8))
    k = jax.random.normal(ks[1], (1, 48, 4, 8))
    v = jax.random.normal(ks[2], (1, 48, 4, 8))
    got = flash_attention(q, k, v, causal=False, q_block=16, kv_block=16)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_naive_last_row():
    ks = jax.random.split(KEY, 3)
    S = 40
    q = jax.random.normal(ks[0], (2, S, 4, 8))
    k = jax.random.normal(ks[1], (2, S, 2, 8))
    v = jax.random.normal(ks[2], (2, S, 2, 8))
    want = naive_attention(q, k, v)[:, -1:]
    kc = jnp.zeros((2, 64, 2, 8)).at[:, :S].set(k)
    vc = jnp.zeros((2, 64, 2, 8)).at[:, :S].set(v)
    got = decode_attention(q[:, -1:], kc, vc, S)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ring_buffer_write():
    k_cache = jnp.zeros((1, 8, 2, 4))
    v_cache = jnp.zeros((1, 8, 2, 4))
    k_new = jnp.ones((1, 1, 2, 4))
    # window 8, position 11 -> slot 3
    kc, vc = write_kv_cache(k_cache, v_cache, k_new, k_new, 11, window=8)
    assert float(kc[0, 3].sum()) == 8.0
    assert float(kc[0, :3].sum()) == 0.0


@given(st.integers(8, 48), st.sampled_from([1, 2, 4]),
       st.sampled_from([8, 16]), st.integers(0, 24))
@settings(max_examples=20, deadline=None)
def test_prop_flash_vs_naive(S, G, qb, window):
    KV, hd = 2, 8
    ks = jax.random.split(jax.random.key(S * 131 + G), 3)
    q = jax.random.normal(ks[0], (1, S, KV * G, hd))
    k = jax.random.normal(ks[1], (1, S, KV, hd))
    v = jax.random.normal(ks[2], (1, S, KV, hd))
    got = flash_attention(q, k, v, window=window, q_block=qb, kv_block=qb)
    want = naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
