"""Cross-model prefix cache: match semantics incl. the SSM state index."""
from repro.core.block_hash import AdapterKey, request_block_hashes
from repro.core.kv_manager import BlockManager
from repro.core.prefix_cache import PrefixCache

BS = 16


def fill(pc: PrefixCache, mgr: BlockManager, tokens, adapter=None,
         salt=()):
    """Simulate a request prefilling `tokens` and completing."""
    hashes = request_block_hashes(tokens, BS, adapter, salt)
    bids = []
    for h in hashes:
        bid = mgr.allocate()
        pc.register_kv_block(h, bid)
        bids.append(bid)
    mgr.release_all(bids)
    return hashes


def make():
    mgr = BlockManager(64, BS)
    return PrefixCache(block_size=BS, kv_manager=mgr), mgr


def test_base_to_alora_reuse():
    pc, mgr = make()
    t = list(range(100))
    fill(pc, mgr, t)
    m = pc.match_and_acquire(t, AdapterKey("a", "alora", 80))
    assert m.n_tokens == 80             # blocks 0..4 end at 80 <= 80
    assert len(m.kv_blocks) == 5


def test_alora_to_base_two_way():
    pc, mgr = make()
    t = list(range(100))
    fill(pc, mgr, t, AdapterKey("a", "alora", 64))
    m = pc.match_and_acquire(t, None)
    assert m.n_tokens == 64             # pre-activation blocks reusable


def test_alora_to_sibling_alora():
    pc, mgr = make()
    t = list(range(100))
    fill(pc, mgr, t, AdapterKey("a1", "alora", 64))
    m = pc.match_and_acquire(t, AdapterKey("a2", "alora", 64))
    assert m.n_tokens == 64


def test_vanilla_lora_no_cross_reuse():
    pc, mgr = make()
    t = list(range(100))
    fill(pc, mgr, t)
    m = pc.match_and_acquire(t, AdapterKey("a", "lora"))
    assert m.n_tokens == 0


def test_miss_releases_nothing_dangling():
    pc, mgr = make()
    t = list(range(100))
    fill(pc, mgr, t)
    before = mgr.num_free()
    m = pc.match_and_acquire(list(range(50, 150)), None)
    assert m.n_tokens == 0
    assert mgr.num_free() == before


def test_state_boundary_consistency():
    """Hybrid archs: reuse depth = deepest boundary with BOTH a state
    snapshot and full KV coverage."""
    kv = BlockManager(64, BS)
    st = BlockManager(8, BS)
    pc = PrefixCache(block_size=BS, kv_manager=kv, state_manager=st)
    t = list(range(96))
    hashes = request_block_hashes(t, BS)
    bids = []
    for h in hashes:                      # KV for all 6 blocks
        b = kv.allocate()
        pc.register_kv_block(h, b)
        bids.append(b)
    kv.release_all(bids)
    s = st.allocate()                     # state snapshot only at block 3
    pc.register_state(hashes[3], s)
    st.release(s)

    m = pc.match_and_acquire(t, None)
    assert m.n_tokens == 4 * BS           # limited by the state snapshot
    assert len(m.kv_blocks) == 4
    assert m.state_slot is not None


def test_pure_ssm_no_kv_constraint():
    st = BlockManager(8, BS)
    pc = PrefixCache(block_size=BS, state_manager=st)
    t = list(range(96))
    hashes = request_block_hashes(t, BS)
    s = st.allocate()
    pc.register_state(hashes[5], s)
    st.release(s)
    m = pc.match_and_acquire(t, None)
    assert m.n_tokens == 6 * BS
    assert m.state_slot is not None
