"""SSD chunk-scan Pallas kernel vs the token-recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import ssd_chunk_ref, ssd_chunk_scan_op

KEY = jax.random.key(0)


def inputs(Bt, S, H, P, N, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P)).astype(dtype)
    B = (jax.random.normal(ks[1], (Bt, S, H, N)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[2], (Bt, S, H, N)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[3], (Bt, S, H)))
    dA = -jnp.exp(jax.random.normal(ks[4], (Bt, S, H)) * 0.3) * dt
    return x, B, C, dA, dt


@pytest.mark.parametrize("Bt,S,H,P,N,chunk", [
    (2, 64, 3, 16, 8, 16),
    (1, 128, 2, 32, 16, 32),
    (2, 17, 1, 8, 4, 8),        # padding path (S % chunk != 0)
    (1, 96, 4, 64, 32, 48),     # bigger state
])
def test_ssd_kernel_matches_oracle(Bt, S, H, P, N, chunk):
    x, B, C, dA, dt = inputs(Bt, S, H, P, N, seed=S)
    y1, s1 = ssd_chunk_scan_op(x, B, C, dA, dt, chunk=chunk,
                               interpret=True)
    y2, s2 = ssd_chunk_ref(x, B, C, dA, dt)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_chunk_invariance():
    x, B, C, dA, dt = inputs(1, 64, 2, 16, 8, seed=3)
    y1, s1 = ssd_chunk_scan_op(x, B, C, dA, dt, chunk=8, interpret=True)
    y2, s2 = ssd_chunk_scan_op(x, B, C, dA, dt, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_matches_model_ssd_forward():
    """The kernel agrees with the model's ssd_forward on the shared
    sub-computation (heads=groups broadcast, D/z/conv stripped)."""
    from repro.configs import get_reduced
    from repro.models.ssm import init_ssm, ssd_forward
    # cross-check via the recurrence oracle only (the model path fuses
    # conv + gating); the oracle is itself validated against ssd_forward
    # through tests/test_ssm.py::test_decode_step_matches_forward.
    x, B, C, dA, dt = inputs(1, 32, 2, 16, 8, seed=9)
    y_k, s_k = ssd_chunk_scan_op(x, B, C, dA, dt, chunk=16,
                                 interpret=True)
    y_r, s_r = ssd_chunk_ref(x, B, C, dA, dt)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
