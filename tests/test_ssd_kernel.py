"""SSD chunk-scan Pallas kernels (dense + ragged) vs the token-recurrence
oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (ragged_ssd_scan_op, ragged_ssd_scan_ref,
                               ssd_chunk_ref, ssd_chunk_scan_op)

KEY = jax.random.key(0)


def inputs(Bt, S, H, P, N, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P)).astype(dtype)
    B = (jax.random.normal(ks[1], (Bt, S, H, N)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[2], (Bt, S, H, N)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[3], (Bt, S, H)))
    dA = -jnp.exp(jax.random.normal(ks[4], (Bt, S, H)) * 0.3) * dt
    return x, B, C, dA, dt


@pytest.mark.parametrize("Bt,S,H,P,N,chunk", [
    (2, 64, 3, 16, 8, 16),
    (1, 128, 2, 32, 16, 32),
    (2, 17, 1, 8, 4, 8),        # padding path (S % chunk != 0)
    (1, 96, 4, 64, 32, 48),     # bigger state
])
def test_ssd_kernel_matches_oracle(Bt, S, H, P, N, chunk):
    x, B, C, dA, dt = inputs(Bt, S, H, P, N, seed=S)
    y1, s1 = ssd_chunk_scan_op(x, B, C, dA, dt, chunk=chunk,
                               interpret=True)
    y2, s2 = ssd_chunk_ref(x, B, C, dA, dt)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_chunk_invariance():
    x, B, C, dA, dt = inputs(1, 64, 2, 16, 8, seed=3)
    y1, s1 = ssd_chunk_scan_op(x, B, C, dA, dt, chunk=8, interpret=True)
    y2, s2 = ssd_chunk_scan_op(x, B, C, dA, dt, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_matches_model_ssd_forward():
    """The kernel agrees with the model's ssd_forward on the shared
    sub-computation (heads=groups broadcast, D/z/conv stripped)."""
    from repro.configs import get_reduced
    from repro.models.ssm import init_ssm, ssd_forward
    # cross-check via the recurrence oracle only (the model path fuses
    # conv + gating); the oracle is itself validated against ssd_forward
    # through tests/test_ssm.py::test_decode_step_matches_forward.
    x, B, C, dA, dt = inputs(1, 32, 2, 16, 8, seed=9)
    y_k, s_k = ssd_chunk_scan_op(x, B, C, dA, dt, chunk=16,
                                 interpret=True)
    y_r, s_r = ssd_chunk_ref(x, B, C, dA, dt)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ragged (packed-axis) variant — the mixed serving step's SSD scan
# ---------------------------------------------------------------------------
def ragged_inputs(lens, H, P, N, S, seed=0):
    T = sum(lens)
    x, B, C, dA, dt = inputs(1, T, H, P, N, seed=seed)
    x, B, C, dA, dt = x[0], B[0], C[0], dA[0], dt[0]
    init = jax.random.normal(jax.random.key(seed + 1), (S, H, N, P))
    seg_ids = np.concatenate(
        [[i] * n for i, n in enumerate(lens)]).astype(np.int32)
    starts = np.zeros(T, bool)
    slots = np.zeros(T, np.int32)
    off = 0
    for i, n in enumerate(lens):
        starts[off] = True
        slots[off:off + n] = i % S
        off += n
    return (x, B, C, dA, dt, jnp.asarray(seg_ids), jnp.asarray(starts),
            jnp.asarray(slots), init)


def ragged_oracle(x, B, C, dA, dt, seg_ids, starts, slots, init):
    """Token-by-token numpy recurrence with per-segment state reset."""
    T, H, P = x.shape
    N = B.shape[-1]
    ys = np.zeros((T, H, P), np.float32)
    sts = np.zeros((T, H, N, P), np.float32)
    state = np.zeros((H, N, P), np.float32)
    for t in range(T):
        if bool(starts[t]):
            state = np.asarray(init[int(slots[t])], np.float32)
        state = np.exp(np.asarray(dA[t]))[:, None, None] * state + \
            np.einsum("hn,hp->hnp",
                      np.asarray(B[t]) * np.asarray(dt[t])[:, None],
                      np.asarray(x[t], np.float32))
        ys[t] = np.einsum("hn,hnp->hp", np.asarray(C[t]), state)
        sts[t] = state
    return ys, sts


@pytest.mark.parametrize("lens", [
    [1, 1, 1, 1],              # decode-only batch
    [1, 1, 12, 23],            # mixed decode + prefill chunks
    [16, 16],                  # block-aligned prefill pair
    [37],                      # single segment
])
def test_ragged_ssd_ref_matches_oracle(lens):
    args = ragged_inputs(lens, H=3, P=16, N=8, S=5, seed=sum(lens))
    y, st = ragged_ssd_scan_ref(args[0], args[1], args[2], args[3],
                                args[4], args[6], args[7], args[8])
    y_o, st_o = ragged_oracle(*args)
    np.testing.assert_allclose(np.asarray(y), y_o, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), st_o, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("lens,chunk", [
    ([1, 1, 12, 23], 8),       # several segment boundaries per chunk
    ([1, 1, 12, 23], 64),      # whole batch in one chunk (+ padding)
    ([16, 16, 16], 16),        # segment boundaries ON chunk boundaries
    ([5, 40], 16),             # segment spanning multiple chunks
])
def test_ragged_ssd_kernel_matches_ref(lens, chunk):
    args = ragged_inputs(lens, H=2, P=16, N=8, S=4, seed=7)
    y_r, st_r = ragged_ssd_scan_ref(args[0], args[1], args[2], args[3],
                                    args[4], args[6], args[7], args[8])
    y_k, st_k = ragged_ssd_scan_op(*args, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               rtol=2e-4, atol=2e-4)


def test_ragged_single_segment_matches_dense_scan():
    """One zero-init segment covering the whole axis must agree with the
    dense single-sequence oracle."""
    x, B, C, dA, dt = inputs(1, 48, 2, 16, 8, seed=11)
    T = 48
    init = jnp.zeros((2, 2, 8, 16))
    starts = jnp.asarray(np.eye(T, 1, dtype=bool)[:, 0])
    slots = jnp.zeros((T,), jnp.int32)
    y_r, st_r = ragged_ssd_scan_ref(x[0], B[0], C[0], dA[0], dt[0],
                                    starts, slots, init)
    y_d, s_d = ssd_chunk_ref(x, B, C, dA, dt)
    np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_d[0]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_r[-1]), np.asarray(s_d[0]),
                               rtol=2e-4, atol=2e-4)
