"""Property tests for the sharding spec trees (hypothesis; skip without).

The sharded serving path trusts ``distributed.sharding`` to hand back
layouts that actually lower: every sharded dim must divide its mesh-axis
product, for EVERY config in ``src/repro/configs`` and every mesh shape
we claim (host test meshes through the production pod meshes).  The
``mesh=`` parameter added for the TP-sharded mixed step guarantees this
by construction (non-divisible dims fall back to replicated) — these
properties pin that contract, plus the ``to_named`` round-trip and the
adapter rank-bucket padding invariants.
"""
import functools

import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import all_configs, get_config, get_reduced
from repro.core.alora import adapter_param_specs
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_decode_caches, param_specs
from repro.serving.adapter_pool import rank_bucket

ARCHS = sorted(all_configs())
# host equivalence meshes → the production pod meshes (launch/mesh.py)
MESHES = [
    {"data": 1, "model": 1},
    {"data": 2, "model": 4},
    {"data": 1, "model": 8},
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
]

COMMON = dict(deadline=None, max_examples=25,
              suppress_health_check=[HealthCheck.data_too_large])


@functools.lru_cache(maxsize=None)
def _cfg(arch, reduced):
    return get_reduced(arch) if reduced else get_config(arch)


@functools.lru_cache(maxsize=None)
def _params_shape(arch, reduced):
    return param_specs(_cfg(arch, reduced))


@functools.lru_cache(maxsize=None)
def _caches_shape(arch, reduced):
    cfg = _cfg(arch, reduced)
    return jax.eval_shape(lambda: init_decode_caches(cfg, 2, 64))


@functools.lru_cache(maxsize=None)
def _adapter_shape(arch, reduced, rank, n):
    return adapter_param_specs(_cfg(arch, reduced), rank, n)


def assert_divides(spec_tree, shape_tree, sizes):
    """Every sharded dim of every leaf divides its axis product."""
    specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    leaves = jax.tree.leaves(shape_tree)
    assert len(specs) == len(leaves)
    for sp, leaf in zip(specs, leaves):
        dims = list(sp) + [None] * (len(leaf.shape) - len(sp))
        assert len(dims) == len(leaf.shape), (sp, leaf.shape)
        for d, ax in zip(leaf.shape, dims):
            if ax is None:
                continue
            n = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                n *= sizes[a]
            assert d % n == 0, (sp, leaf.shape, ax, n)


# ---------------------------------------------------------------------------
# divisibility: mesh-validated spec trees always lower
# ---------------------------------------------------------------------------
@settings(**COMMON)
@given(arch=st.sampled_from(ARCHS), mesh=st.sampled_from(MESHES),
       reduced=st.booleans())
def test_param_specs_divide(arch, mesh, reduced):
    cfg = _cfg(arch, reduced)
    shape = _params_shape(arch, reduced)
    specs = shd.param_specs_tree(cfg, shape, mesh=mesh)
    assert_divides(specs, shape, mesh)


@settings(**COMMON)
@given(arch=st.sampled_from(ARCHS), mesh=st.sampled_from(MESHES),
       rank=st.sampled_from([4, 8, 32]), n=st.integers(1, 5))
def test_adapter_specs_divide(arch, mesh, rank, n):
    """Stacked-adapter trees (and the pool's per-layer slot stacks, which
    reuse the same leaf rules through ``adapter_slot_specs``): A always
    replicated, B sharded only where its output dim divides."""
    cfg = _cfg(arch, True)
    shape = _adapter_shape(arch, True, rank, n)
    specs = shd.adapter_specs_tree(cfg, shape, mesh=mesh)
    assert_divides(specs, shape, mesh)
    # A factors ((..., d, r) leaves) are replicated — rank ≪ d never pays
    # a collective
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    for path, sp in flat:
        name = str(path[-1].key)
        if name.startswith("a"):
            assert all(ax is None for ax in sp), (name, sp)


@settings(**COMMON)
@given(arch=st.sampled_from(ARCHS), mesh=st.sampled_from(MESHES),
       reduced=st.booleans())
def test_cache_specs_divide(arch, mesh, reduced):
    """Dense decode-cache trees resolve heads-vs-head_dim against the
    actual mesh; valid combos always divide."""
    cfg = _cfg(arch, reduced)
    ms = mesh["model"]
    if not (cfg.num_kv_heads % ms == 0 and cfg.num_heads % ms == 0
            or cfg.head_dim % ms == 0):
        pytest.skip("arch does not support this model-axis width")
    shape = _caches_shape(arch, reduced)
    specs = shd.cache_specs_tree(cfg, shape, mesh, batch_axes=("data",),
                                 batch_shardable=False)
    assert_divides(specs, shape, mesh)
    # the scalar helper shares the per-leaf tree's heads-vs-head_dim rule
    kv = shd.kv_cache_spec(cfg, ("data",), "model", batch_shardable=False,
                           mesh=mesh)
    assert (tuple(kv)[4] == "model") == (
        cfg.num_kv_heads % ms == 0 and cfg.num_heads % ms == 0)


@settings(**COMMON)
@given(arch=st.sampled_from(ARCHS), mesh=st.sampled_from(MESHES))
def test_mixed_step_shardings_divide(arch, mesh):
    """The serving pools' StepShardings divide the actual pool dims the
    runner allocates (block_size 16, pow2 pool sizes)."""
    cfg = _cfg(arch, True)
    ms = mesh["model"]
    if not (cfg.num_kv_heads % ms == 0 and cfg.num_heads % ms == 0
            or cfg.head_dim % ms == 0):
        pytest.skip("arch does not support this model-axis width")
    sh = shd.mixed_step_shardings(cfg, mesh)
    kv_shape = (max(cfg.num_attn_layers(), 1), 64, 16, cfg.num_kv_heads,
                cfg.head_dim)
    assert_divides(sh.kv_pool, [jax.ShapeDtypeStruct(kv_shape, "f4")], mesh)
    if cfg.num_ssm_layers():
        from repro.models.ssm import ssm_dims
        _, nh, ch = ssm_dims(cfg)
        s = cfg.ssm
        assert_divides(sh.ssm_pool, [jax.ShapeDtypeStruct(
            (cfg.num_ssm_layers(), 8, nh, s.state_dim, s.head_dim), "f4")],
            mesh)
        assert_divides(sh.conv_pool, [jax.ShapeDtypeStruct(
            (cfg.num_ssm_layers(), 8, s.conv_width - 1, ch), "f4")], mesh)


# ---------------------------------------------------------------------------
# data-parallel token axis (EngineConfig.data_shard_tokens layouts)
# ---------------------------------------------------------------------------
@settings(**COMMON)
@given(arch=st.sampled_from(ARCHS), mesh=st.sampled_from(MESHES))
def test_token_axis_specs(arch, mesh):
    """Token-axis layouts activate exactly when a data axis with size
    > 1 is requested: tok_meta/tok_embeds carry P(data)/P(data, None)
    and attn_out's leading (token) dim follows; otherwise — no request,
    or a size-1 axis — everything stays replicated (P(None) layouts),
    for every config × mesh shape."""
    cfg = _cfg(arch, True)
    ms = mesh["model"]
    if not (cfg.num_kv_heads % ms == 0 and cfg.num_heads % ms == 0
            or cfg.head_dim % ms == 0):
        pytest.skip("arch does not support this model-axis width")
    base = shd.mixed_step_shardings(cfg, mesh)
    assert base.tok_meta == P(None)
    assert base.tok_embeds == P(None, None)
    assert tuple(base.attn_out)[0] is None
    ds = shd.mixed_step_shardings(cfg, mesh, data_axis="data")
    want = "data" if mesh["data"] > 1 else None
    assert ds.tok_meta == P(want)
    assert ds.tok_embeds == P(want, None)
    assert tuple(ds.attn_out)[0] == want
    # the TP pool layouts are untouched by token sharding
    assert ds.kv_pool == base.kv_pool
    assert ds.ssm_pool == base.ssm_pool and ds.conv_pool == base.conv_pool


@settings(**COMMON)
@given(n=st.integers(1, 4096), lo=st.sampled_from([1, 2, 4, 8, 16]))
def test_token_bucket_floor(n, lo):
    """The runner's pow2 token buckets double FROM the data-axis size,
    so every bucket divides the axis and P(data) always lowers."""
    from repro.serving.runner import next_pow2
    b = next_pow2(n, lo=lo)
    assert b >= n and b >= lo
    assert b % lo == 0
    assert b & (b - 1) == 0                    # still pow2
    assert b < 2 * max(n, lo)                  # tight: no over-padding


# ---------------------------------------------------------------------------
# to_named round-trip on a real mesh
# ---------------------------------------------------------------------------
@settings(**COMMON)
@given(arch=st.sampled_from(ARCHS))
def test_to_named_round_trips(arch):
    """to_named wraps every P into a NamedSharding on the mesh, keeping
    tree structure and spec values (the spec is recoverable leaf for
    leaf) — on every config in src/repro/configs/."""
    cfg = _cfg(arch, True)
    shape = _params_shape(arch, True)
    mesh = make_host_mesh()
    specs = shd.param_specs_tree(cfg, shape, mesh=mesh)
    named = shd.to_named(specs, mesh)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_n = jax.tree.leaves(
        named, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))
    assert len(flat_s) == len(flat_n)
    for sp, ns in zip(flat_s, flat_n):
        assert isinstance(ns, jax.sharding.NamedSharding)
        assert ns.mesh == mesh
        assert tuple(ns.spec) == tuple(sp)
    assert jax.tree.structure(
        named, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)
    ) == jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# rank-bucket padding invariants (the slot shape every adapter pads into)
# ---------------------------------------------------------------------------
@settings(**COMMON)
@given(rank=st.integers(1, 96))
def test_rank_bucket_properties(rank):
    b = rank_bucket(rank)
    assert b >= 8 and b >= rank
    assert b & (b - 1) == 0                    # pow2
    assert b < 2 * max(rank, 8)                # tight: no over-padding


@settings(**COMMON)
@given(arch=st.sampled_from(ARCHS), rank=st.integers(1, 32))
def test_rank_padding_fills_bucket(arch, rank):
    """pad_adapter_rank lands every adapter exactly on the bucket shape:
    A widens on its last dim, B on its second-to-last, nothing else."""
    from repro.core.alora import pad_adapter_rank
    cfg = _cfg(arch, True)
    bucket = rank_bucket(rank)
    w = _adapter_shape(arch, True, rank, 0)    # n=0 ⇒ only the zero slot
    padded = jax.eval_shape(lambda t: pad_adapter_rank(t, bucket), w)
    flat_w, _ = jax.tree_util.tree_flatten_with_path(w)
    flat_p = jax.tree.leaves(padded)
    for (path, lw), lp in zip(flat_w, flat_p):
        name = str(path[-1].key)
        axis = -1 if name.startswith("a") else -2
        expect = list(lw.shape)
        expect[axis] += bucket - rank
        assert list(lp.shape) == expect, (name, lw.shape, lp.shape)
