"""Dynamic adapter lifecycle: the paged adapter-slot pool.

Covers the subsystem's contract end to end:
 1. registry semantics — register/unregister at any time, versioned
    uids, heterogeneous ranks padded into the slot bucket (exactly);
 2. pool mechanics — pin-while-scheduled ref counts, LRU eviction of
    unpinned slots only, acquire failure when everything is pinned,
    prefetch/install/stall counters; the bounded staging tier (budget
    deferral, TTL expiry of unclaimed stages, evict-policy hook) and
    the adapter-aware admission scheduler (blocked-head skip,
    starvation-age cap, churn + preemption under reordering);
 3. engine equivalence under churn — more adapters registered than
    device slots, interleaved admissions/evictions/readmissions, output
    token-identical to the all-resident sequential oracle;
 4. grouped-LoRA impls (dense oracle / ragged ref / Pallas interpret)
    agree through the mixed step;
 5. cache-identity regressions — slot reuse and name re-registration
    can never alias prefix-cache entries across adapters.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.alora import (AdapterSpec, init_adapter_weights,
                              pad_adapter_rank, stack_adapters)
from repro.models import init_params
from repro.models.layers import lora_delta
from repro.serving import Engine, EngineConfig
from repro.serving.adapter_pool import AdapterPool, rank_bucket

KEY = jax.random.key(0)
INV = (7, 8, 9)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("granite-3.2-8b")
    params = init_params(KEY, cfg)
    return cfg, params


def mk_weights(cfg, seed, rank=8, scale=1.0):
    w = init_adapter_weights(jax.random.key(seed), cfg, rank)
    if scale != 1.0:
        w = jax.tree.map(lambda x: x * scale, w)
    return w


def prompt_of(n, seed=0, vocab=500):
    return list(np.random.RandomState(seed).randint(10, vocab, n))


# ---------------------------------------------------------------------------
# 1. registry + rank padding
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_register_unregister_versioned_uids(self, setup):
        cfg, _ = setup
        pool = AdapterPool(cfg, num_slots=2, slot_rank=8)
        u1 = pool.register(AdapterSpec("a", rank=8), mk_weights(cfg, 1))
        assert u1 == "a#v1"
        with pytest.raises(ValueError):
            pool.register(AdapterSpec("a", rank=8), mk_weights(cfg, 2))
        pool.unregister("a")
        u2 = pool.register(AdapterSpec("a", rank=8), mk_weights(cfg, 2))
        assert u2 == "a#v2" and u2 != u1       # identity never recycled
        with pytest.raises(KeyError):
            pool.unregister("nope")

    def test_rank_over_bucket_rejected(self, setup):
        cfg, _ = setup
        pool = AdapterPool(cfg, num_slots=1, slot_rank=8)
        with pytest.raises(ValueError):
            pool.register(AdapterSpec("big", rank=16),
                          mk_weights(cfg, 1, rank=16))

    def test_rank_padding_is_exact(self, setup):
        """x @ [A|0] @ [B;0] == x @ A @ B — the zero-block invariant the
        bucketed slot shapes rely on."""
        cfg, _ = setup
        w = mk_weights(cfg, 3, rank=8)
        padded = pad_adapter_rank(w, 32)
        seg, seg_p = w["seg0"], padded["seg0"]
        assert seg_p["aq"].shape[-1] == 32 and seg_p["bq"].shape[-2] == 32
        x = jax.random.normal(jax.random.key(9), (6, cfg.d_model))
        idx = np.ones(6, np.int32)
        for a_k, b_k in (("aq", "bq"), ("ak", "bk"), ("av", "bv")):
            d0 = lora_delta(x, jax.numpy.stack(
                [jax.numpy.zeros_like(seg[a_k][0, 0]), seg[a_k][0, 0]]),
                jax.numpy.stack([jax.numpy.zeros_like(seg[b_k][0, 0]),
                                 seg[b_k][0, 0]]), idx)
            d1 = lora_delta(x, jax.numpy.stack(
                [jax.numpy.zeros_like(seg_p[a_k][0, 0]),
                 seg_p[a_k][0, 0]]),
                jax.numpy.stack([jax.numpy.zeros_like(seg_p[b_k][0, 0]),
                                 seg_p[b_k][0, 0]]), idx)
            np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))

    def test_stack_adapters_mixes_ranks(self, setup):
        """The old `equal-rank` assertion is gone: heterogeneous ranks
        stack into one bucketed tensor."""
        cfg, _ = setup
        stacked = stack_adapters(
            cfg, [mk_weights(cfg, 1, rank=4), mk_weights(cfg, 2, rank=16)],
            16)
        assert stacked["seg0"]["aq"].shape[2] == 3      # zero + 2
        assert stacked["seg0"]["aq"].shape[-1] == 16

    def test_rank_bucket(self):
        assert [rank_bucket(r) for r in (1, 8, 9, 32, 33)] == \
            [8, 8, 16, 32, 64]


# ---------------------------------------------------------------------------
# 2. pool mechanics: pins, LRU eviction, prefetch counters
# ---------------------------------------------------------------------------
class TestPoolMechanics:
    def mk_pool(self, cfg, n_regs=3, num_slots=2):
        pool = AdapterPool(cfg, num_slots=num_slots, slot_rank=8)
        uids = [pool.register(AdapterSpec(f"a{i}", rank=8),
                              mk_weights(cfg, i)) for i in range(n_regs)]
        return pool, uids

    def test_pin_blocks_eviction(self, setup):
        cfg, _ = setup
        pool, (u0, u1, u2) = self.mk_pool(cfg)
        s0, s1 = pool.acquire(u0), pool.acquire(u1)
        assert {s0, s1} == {1, 2} and pool.occupancy == 2
        assert pool.acquire(u2) is None          # everything pinned
        assert pool.acquire_fails == 1
        pool.release(u0)
        s2 = pool.acquire(u2)                    # evicts u0 (unpinned LRU)
        assert s2 == s0 and pool.evictions == 1
        assert pool.get(u0).slot is None
        assert pool.get(u1).slot == s1           # pinned survivor intact

    def test_lru_prefers_least_recently_acquired(self, setup):
        cfg, _ = setup
        pool, (u0, u1, u2) = self.mk_pool(cfg)
        pool.acquire(u0)
        pool.acquire(u1)
        pool.release(u0)
        pool.release(u1)
        pool.acquire(u0)                         # refresh u0's recency
        pool.release(u0)
        pool.acquire(u2)                         # must evict u1, not u0
        assert pool.get(u1).slot is None
        assert pool.get(u0).slot is not None

    def test_release_underflow_asserts(self, setup):
        cfg, _ = setup
        pool, uids = self.mk_pool(cfg)
        pool.acquire(uids[0])
        pool.release(uids[0])
        with pytest.raises(AssertionError):
            pool.release(uids[0])

    def test_unregister_pinned_refuses(self, setup):
        cfg, _ = setup
        pool, uids = self.mk_pool(cfg)
        pool.acquire(uids[0])
        with pytest.raises(RuntimeError):
            pool.unregister("a0")
        pool.release(uids[0])
        pool.unregister("a0")                    # frees the slot
        assert pool.occupancy == 0

    def test_prefetch_then_acquire_never_stalls(self, setup):
        cfg, _ = setup
        pool, uids = self.mk_pool(cfg)
        pool.prefetch(uids[0])
        assert pool.prefetch_issued == 1
        pool.prefetch(uids[0])                   # already staged: no-op
        assert pool.prefetch_issued == 1
        pool.acquire(uids[0])                    # install hit the staging
        assert pool.prefetch_hits == 1
        assert pool.stalled_installs == 0
        pool.acquire(uids[1])                    # no prefetch first
        assert pool.stalled_installs == 1
        assert pool.prefetch_hits == 1
        # re-acquiring a resident slot is a warm hit
        pool.release(uids[0])
        pool.acquire(uids[0])
        assert pool.resident_hits == 1

    def test_residency_costs_one_weight_copy(self, setup):
        """Installing scatters the staged weights into the slot stack
        and frees the staging copy; eviction leaves none behind."""
        cfg, _ = setup
        pool, (u0, u1, u2) = self.mk_pool(cfg)
        pool.prefetch(u0)
        assert pool.get(u0).device_layers is not None
        pool.acquire(u0)
        assert pool.get(u0).device_layers is None    # staging freed
        pool.release(u0)
        pool.acquire(u1)
        pool.acquire(u2)                             # evicts u0
        assert pool.get(u0).slot is None
        assert pool.get(u0).device_layers is None

    def test_installed_weights_land_in_slot(self, setup):
        """The slot row of the layer stack must equal the (padded)
        registered weights; slot 0 stays exactly zero."""
        cfg, _ = setup
        pool, uids = self.mk_pool(cfg)
        slot = pool.acquire(uids[1])
        reg = pool.get(uids[1])
        got = np.asarray(pool.layers[0]["aq"][slot])
        want = np.asarray(reg.host_layers[0]["aq"])
        np.testing.assert_array_equal(got, want)
        assert not np.asarray(pool.layers[0]["aq"][0]).any()


# ---------------------------------------------------------------------------
# 2b. staging tier: bounded prefetch, TTL expiry, evict-policy hook
# ---------------------------------------------------------------------------
class TestStagingTier:
    def mk_pool(self, cfg, n_regs=3, num_slots=2, **kw):
        pool = AdapterPool(cfg, num_slots=num_slots, slot_rank=8, **kw)
        uids = [pool.register(AdapterSpec(f"a{i}", rank=8),
                              mk_weights(cfg, i)) for i in range(n_regs)]
        return pool, uids

    def test_unclaimed_stage_expires(self, setup):
        """Regression for the prefetch leak: a stage no admission ever
        claims (cancelled / drained / routed-away request) is dropped
        after ``staging_ttl`` ticks and its device copy freed."""
        cfg, _ = setup
        pool, (u0, *_) = self.mk_pool(cfg, staging_ttl=3)
        assert pool.prefetch(u0)
        assert pool.staged_now == 1
        assert pool.get(u0).device_layers is not None
        for _ in range(3):
            pool.tick()
        assert pool.staged_now == 1          # within TTL: still staged
        pool.tick()                          # age > ttl: expired
        assert pool.staged_now == 0
        assert pool.staged_dropped == 1
        assert pool.get(u0).device_layers is None
        # the registration is intact: a later prefetch restages
        assert pool.prefetch(u0)
        assert pool.staged_now == 1

    def test_refresh_resets_stage_age(self, setup):
        """The scheduler re-prefetches queued requests every step; each
        call refreshes the stage's age (no new H2D) so a stage a live
        request still wants never expires under it."""
        cfg, _ = setup
        pool, (u0, *_) = self.mk_pool(cfg, staging_ttl=2)
        pool.prefetch(u0)
        for _ in range(6):                   # re-prefetch every tick
            pool.tick()
            assert pool.prefetch(u0)
        assert pool.staged_now == 1
        assert pool.prefetch_issued == 1     # one transfer, many refreshes
        for _ in range(3):                   # stop refreshing
            pool.tick()
        assert pool.staged_now == 0
        assert pool.staged_dropped == 1

    def test_staging_budget_defers_prefetch(self, setup):
        """The staging tier is BOUNDED: a prefetch past the budget is
        deferred (returns False) instead of stacking device copies."""
        cfg, _ = setup
        pool, (u0, u1, u2) = self.mk_pool(cfg, staging_budget=1)
        assert pool.prefetch(u0)
        assert not pool.prefetch(u1)
        assert pool.prefetch_deferred == 1
        assert pool.staged_now == 1
        assert pool.get(u1).device_layers is None
        pool.acquire(u0)                     # install claims the stage
        assert pool.staged_now == 0
        assert pool.prefetch(u1)             # budget freed: staged now
        assert pool.staged_now == 1

    def test_install_claims_stage_not_counted_dropped(self, setup):
        cfg, _ = setup
        pool, (u0, *_) = self.mk_pool(cfg)
        pool.prefetch(u0)
        pool.acquire(u0)
        assert pool.staged_now == 0
        assert pool.staged_dropped == 0      # claimed, not leaked
        assert pool.prefetch_hits == 1

    def test_acquire_stall_bypasses_budget(self, setup):
        """An admission-path stall stages directly even at budget — the
        install claims the copy in the same call, nothing lingers."""
        cfg, _ = setup
        pool, (u0, u1, u2) = self.mk_pool(cfg, staging_budget=1)
        pool.prefetch(u0)                    # budget now full
        slot = pool.acquire(u1)              # never prefetched: stall path
        assert slot is not None
        assert pool.stalled_installs == 1
        assert pool.get(u1).slot == slot
        assert pool.staged_now == 1          # only u0's stage remains

    def test_drop_unclaimed_stages_frees_all_now(self, setup):
        """Regression for the drained-replica stage pin: a stopped
        replica never ticks again, so TTL expiry can't run — the drain
        path drops every unclaimed stage eagerly instead."""
        cfg, _ = setup
        pool, (u0, u1, _) = self.mk_pool(cfg, staging_ttl=100)
        assert pool.prefetch(u0) and pool.prefetch(u1)
        assert pool.staged_now == 2
        assert pool.drop_unclaimed_stages() == 2
        assert pool.staged_now == 0
        assert pool.staged_dropped == 2
        assert pool.get(u0).device_layers is None
        assert pool.get(u1).device_layers is None
        # registrations intact: a later prefetch restages on demand
        assert pool.prefetch(u0)
        assert pool.staged_now == 1
        # idempotent once drained
        assert pool.drop_unclaimed_stages() == 1
        assert pool.drop_unclaimed_stages() == 0

    def test_unregister_drops_stage(self, setup):
        cfg, _ = setup
        pool, (u0, *_) = self.mk_pool(cfg)
        pool.prefetch(u0)
        pool.unregister("a0")
        assert pool.staged_now == 0
        assert pool.staged_dropped == 1

    def test_evict_policy_hook_picks_victim(self, setup):
        """The eviction-policy hook sees the unpinned residents in
        least-recently-acquired-first order and overrides the default
        LRU choice."""
        cfg, _ = setup
        pool, (u0, u1, u2) = self.mk_pool(
            cfg, evict_policy=lambda cands: cands[-1])   # MRU victim
        pool.acquire(u0)
        pool.release(u0)
        pool.acquire(u1)
        pool.release(u1)
        pool.acquire(u2)                     # default LRU would evict u0
        assert pool.get(u1).slot is None     # hook evicted the MRU
        assert pool.get(u0).slot is not None

    def test_evict_policy_must_return_candidate(self, setup):
        cfg, _ = setup
        pool, (u0, u1, u2) = self.mk_pool(
            cfg, evict_policy=lambda cands: "nope#v1")
        pool.acquire(u0)
        pool.release(u0)
        pool.acquire(u1)
        pool.release(u1)
        with pytest.raises(AssertionError):
            pool.acquire(u2)

    def test_affinity_classes_and_slot_gate(self, setup):
        """host-only -> staged -> resident is 0 -> 1 -> 2 (the admission
        ordering key); can_take_slot flips with pins (the scan's
        doomed-acquire gate)."""
        cfg, _ = setup
        pool, (u0, u1, _) = self.mk_pool(cfg, num_slots=1)
        assert pool.affinity_of(u0) == 0 and pool.affinity("a0") == 0
        pool.prefetch(u0)
        assert pool.affinity_of(u0) == 1
        assert pool.can_take_slot()          # a free slot exists
        pool.acquire(u0)
        assert pool.affinity_of(u0) == 2 and pool.affinity("a0") == 2
        assert not pool.can_take_slot()      # sole slot pinned
        pool.release(u0)
        assert pool.can_take_slot()          # unpinned resident victim
        assert pool.affinity("unknown") == 0


# ---------------------------------------------------------------------------
# 3. engine-level: churn equivalence + heterogeneous ranks
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def eng_setup(setup):
    cfg, params = setup
    specs = [AdapterSpec(f"ad{i}", rank=(4 if i % 2 else 8),
                         invocation_tokens=tuple(t + i for t in INV))
             for i in range(4)]
    weights = [mk_weights(cfg, 100 + i, rank=s.rank, scale=4.0)
               for i, s in enumerate(specs)]
    return cfg, params, specs, weights


def churn_workload(eng, specs, reps=2, gen=4):
    rids = []
    k = 0
    for rep in range(reps):
        for i, s in enumerate(specs):
            p = prompt_of(28, seed=rep * 10 + i) + list(s.invocation_tokens)
            rids.append(eng.submit(p, gen, adapter_name=s.name,
                                   arrival_time=1e-9 * k))
            k += 1
    eng.run_until_idle()
    return [eng.request(r).output_tokens for r in rids]


def test_churn_matches_all_resident_oracle(eng_setup):
    """N registered > S resident slots, admissions interleaved with
    decode so slots cycle; outputs must be token-identical to the
    all-resident sequential oracle, and accounting must drain clean."""
    cfg, params, specs, weights = eng_setup
    ads = list(zip(specs, weights))
    eng_o = Engine(cfg, params, adapters=ads,
                   engine_cfg=EngineConfig(execution_mode="sequential",
                                           max_running=3))
    oracle = churn_workload(eng_o, specs)
    assert eng_o.adapter_pool.evictions == 0     # oracle: all resident

    eng = Engine(cfg, params, adapters=ads,
                 engine_cfg=EngineConfig(adapter_slots=2, max_running=3))
    out = churn_workload(eng, specs)
    assert out == oracle
    st = eng.adapter_pool_stats()
    assert st.evictions > 0                      # slots actually cycled
    assert st.num_registered == 4 and st.num_slots == 2
    # pin accounting drains to zero; KV pool fully released
    assert eng.adapter_pool.pinned_slots() == 0
    assert all(eng.adapter_pool.get(eng.adapter_pool.uid_of(s.name)).pins
               == 0 for s in specs)
    assert eng.kv_mgr.num_free() == eng.ecfg.num_blocks


def test_register_evict_readmit_interleaved_with_decode(eng_setup):
    """Registration happens mid-serving (while other requests decode);
    a previously-evicted adapter is readmitted and must produce the same
    tokens as its first run."""
    cfg, params, specs, weights = eng_setup
    eng = Engine(cfg, params, adapters=[(specs[0], weights[0])],
                 engine_cfg=EngineConfig(adapter_slots=2, max_running=3))
    p0 = prompt_of(28, seed=1) + list(specs[0].invocation_tokens)
    r0 = eng.submit(p0, 8, adapter_name="ad0")
    eng.step()                                   # ad0 admitted + running
    for i in (1, 2):                             # register mid-decode
        eng.register_adapter(specs[i], weights[i])
    r1 = eng.submit(prompt_of(28, seed=2)
                    + list(specs[1].invocation_tokens), 4,
                    adapter_name="ad1")
    r2 = eng.submit(prompt_of(28, seed=3)
                    + list(specs[2].invocation_tokens), 4,
                    adapter_name="ad2")
    eng.run_until_idle()
    first = eng.request(r0).output_tokens
    # readmit ad0 after it may have been evicted: identical continuation
    r3 = eng.submit(p0, 8, adapter_name="ad0")
    eng.run_until_idle()
    assert eng.request(r3).output_tokens == first
    assert len(eng.request(r1).output_tokens) == 4
    assert len(eng.request(r2).output_tokens) == 4


def test_heterogeneous_ranks_match_equal_rank_oracle(eng_setup):
    """An engine mixing rank-4 and rank-8 adapters must emit exactly the
    tokens of per-adapter equal-rank engines (padding is exact)."""
    cfg, params, specs, weights = eng_setup
    eng = Engine(cfg, params, adapters=list(zip(specs[:2], weights[:2])),
                 engine_cfg=EngineConfig())
    assert eng.adapter_pool.slot_rank == 8       # bucket of max rank
    prompts = [prompt_of(24, seed=i) + list(specs[i].invocation_tokens)
               for i in range(2)]
    rids = [eng.submit(p, 4, adapter_name=f"ad{i}")
            for i, p in enumerate(prompts)]
    eng.run_until_idle()
    for i in range(2):
        solo = Engine(cfg, params, adapters=[(specs[i], weights[i])],
                      engine_cfg=EngineConfig())
        r = solo.submit(prompts[i], 4, adapter_name=f"ad{i}")
        solo.run_until_idle()
        assert solo.request(r).output_tokens == \
            eng.request(rids[i]).output_tokens


def _impl_tokens(eng_setup, impl):
    cfg, params, specs, weights = eng_setup
    eng = Engine(cfg, params, adapters=list(zip(specs[:3], weights[:3])),
                 engine_cfg=EngineConfig(mixed_lora_impl=impl,
                                         adapter_slots=2))
    return churn_workload(eng, specs[:3], reps=1)


@pytest.fixture(scope="module")
def dense_lora_tokens(eng_setup):
    return _impl_tokens(eng_setup, "dense")


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
def test_mixed_lora_impls_agree(eng_setup, dense_lora_tokens, impl):
    """The grouped ragged-LoRA path (jnp ref and Pallas kernel) must
    emit the same tokens as the dense stacked-scan oracle, through the
    mixed step and under slot churn."""
    assert _impl_tokens(eng_setup, impl) == dense_lora_tokens


# ---------------------------------------------------------------------------
# 4. cache-identity regressions (uid keying, never slot / bare name)
# ---------------------------------------------------------------------------
def test_slot_reuse_never_aliases_prefix_cache(setup):
    """Adapter B inherits adapter A's just-evicted slot; with slot-index
    (or unstable) cache keys B would hit A's cached blocks.  It must
    miss them."""
    cfg, params = setup
    wa = mk_weights(cfg, 50, scale=4.0)
    wb = mk_weights(cfg, 51, scale=4.0)
    sa = AdapterSpec("A", rank=8)                # vanilla lora: every
    sb = AdapterSpec("B", rank=8)                # block adapter-salted
    eng = Engine(cfg, params, adapters=[(sa, wa), (sb, wb)],
                 engine_cfg=EngineConfig(adapter_slots=1, max_running=1))
    p = prompt_of(48, seed=5)
    ra = eng.submit(p, 2, adapter_name="A")
    eng.run_until_idle()
    rb = eng.submit(p, 2, adapter_name="B")      # evicts A, reuses slot 1
    eng.run_until_idle()
    assert eng.request(ra).adapter_slot == 0     # released
    assert eng.adapter_pool.evictions == 1
    assert eng.request(rb).n_cache_hit_tokens == 0
    # positive control: A again — ITS blocks are still hash-reachable
    ra2 = eng.submit(p, 2, adapter_name="A")
    eng.run_until_idle()
    assert eng.request(ra2).n_cache_hit_tokens > 0
    assert eng.request(ra2).output_tokens == eng.request(ra).output_tokens


def test_reregistered_name_never_reuses_old_cache(setup):
    """Unregister 'ad', register different weights under the same name:
    the new registration (new uid) must not hit the old blocks, while
    identical resubmission under the old registration did."""
    cfg, params = setup
    s = AdapterSpec("ad", rank=8)
    eng = Engine(cfg, params,
                 adapters=[(s, mk_weights(cfg, 60, scale=4.0))],
                 engine_cfg=EngineConfig())
    p = prompt_of(48, seed=6)
    r1 = eng.submit(p, 2, adapter_name="ad")
    eng.run_until_idle()
    r2 = eng.submit(p, 2, adapter_name="ad")     # same uid: cache hit
    eng.run_until_idle()
    assert eng.request(r2).n_cache_hit_tokens > 0
    eng.unregister_adapter("ad")
    eng.register_adapter(s, mk_weights(cfg, 61, scale=4.0))
    r3 = eng.submit(p, 2, adapter_name="ad")     # new uid: MUST miss
    eng.run_until_idle()
    assert eng.request(r3).n_cache_hit_tokens == 0
    assert eng.request(r3).adapter_key() != eng.request(r1).adapter_key()


def test_alora_base_reuse_survives_uid_keying(setup):
    """The paper's cross-model reuse must be unaffected: pre-activation
    aLoRA blocks stay base-aligned (no uid in their hash), so a base
    prefill still feeds an aLoRA request after re-registration."""
    cfg, params = setup
    s = AdapterSpec("uq", rank=8, invocation_tokens=INV)
    eng = Engine(cfg, params, adapters=[(s, mk_weights(cfg, 70))],
                 engine_cfg=EngineConfig())
    x = prompt_of(64, seed=7)
    rb = eng.submit(x, 4, adapter_name=None)     # base fills the prefix
    eng.run_until_idle()
    y = eng.request(rb).output_tokens
    r1 = eng.submit(x + y + list(INV), 2, adapter_name="uq")
    eng.run_until_idle()
    assert eng.request(r1).n_cache_hit_tokens > 0
    eng.unregister_adapter("uq")
    eng.register_adapter(s, mk_weights(cfg, 71))
    r2 = eng.submit(x + y + list(INV) + [3], 2, adapter_name="uq")
    eng.run_until_idle()
    assert eng.request(r2).n_cache_hit_tokens > 0   # base blocks reused


# ---------------------------------------------------------------------------
# 5. scheduler accounting under slot scarcity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["fcfs", "affinity"])
def test_admission_queues_behind_pinned_slots(eng_setup, policy):
    """With one adapter slot and two long-running adapter requests, the
    second must wait for the first to UNPIN (finish), then complete —
    no deadlock, no double-pin.  Strict FCFS pays an acquire_fail per
    retry of the blocked head; the affinity scan sees the doomed
    acquire coming (``can_take_slot``) and never issues it."""
    cfg, params, specs, weights = eng_setup
    eng = Engine(cfg, params, adapters=list(zip(specs[:2], weights[:2])),
                 engine_cfg=EngineConfig(adapter_slots=1, max_running=4,
                                         admission_policy=policy))
    r0 = eng.submit(prompt_of(24, seed=1)
                    + list(specs[0].invocation_tokens), 6,
                    adapter_name="ad0")
    r1 = eng.submit(prompt_of(24, seed=2)
                    + list(specs[1].invocation_tokens), 6,
                    adapter_name="ad1")
    eng.step()
    assert eng.request(r0).adapter_slot == 1
    assert eng.request(r1).adapter_slot == 0     # queued behind eviction
    if policy == "fcfs":
        assert eng.adapter_pool_stats().acquire_fails >= 1
    else:
        assert eng.adapter_pool_stats().acquire_fails == 0
    eng.run_until_idle()
    assert len(eng.request(r1).output_tokens) == 6
    assert eng.adapter_pool.pinned_slots() == 0


def test_affinity_admits_past_blocked_head(eng_setup):
    """A request whose adapter cannot pin a slot must not head-block a
    resident-adapter request queued behind it: the affinity scan skips
    the blocked head (bumping its admission_skips) and admits the
    resident one, while strict FCFS stays stuck on the head."""
    cfg, params, specs, weights = eng_setup

    def run(policy):
        eng = Engine(cfg, params,
                     adapters=list(zip(specs[:2], weights[:2])),
                     engine_cfg=EngineConfig(adapter_slots=1,
                                             max_running=3,
                                             admission_policy=policy))
        r0 = eng.submit(prompt_of(24, seed=1)
                        + list(specs[0].invocation_tokens), 8,
                        adapter_name="ad0", arrival_time=0.0)
        eng.step()                      # ad0 resident + pinned
        rb = eng.submit(prompt_of(24, seed=2)
                        + list(specs[1].invocation_tokens), 4,
                        adapter_name="ad1", arrival_time=1e-9)
        ra = eng.submit(prompt_of(24, seed=3)
                        + list(specs[0].invocation_tokens), 4,
                        adapter_name="ad0", arrival_time=2e-9)
        eng.step()
        admitted = {r.req_id for r in eng.running}
        skips = eng.request(rb).admission_skips
        eng.run_until_idle()
        return eng, rb, ra, admitted, skips

    eng, rb, ra, admitted, skips = run("affinity")
    assert ra in admitted and rb not in admitted
    assert skips >= 1                            # overtaken, and counted
    assert len(eng.request(rb).output_tokens) == 4   # still completes
    assert eng.adapter_pool.pinned_slots() == 0
    eng, rb, ra, admitted, _ = run("fcfs")
    assert ra not in admitted and rb not in admitted  # head-blocked


def test_starvation_cap_bounds_bypass(eng_setup):
    """Property: no waiting request is ever overtaken by younger
    admissions more than ``admission_starvation_cap`` times — once
    capped it barriers the window until it admits."""
    cfg, params, specs, weights = eng_setup
    cap = 2
    eng = Engine(cfg, params, adapters=list(zip(specs[:2], weights[:2])),
                 engine_cfg=EngineConfig(adapter_slots=1, max_running=2,
                                         admission_starvation_cap=cap))
    hold = eng.submit(prompt_of(24, seed=0)
                      + list(specs[0].invocation_tokens), 24,
                      adapter_name="ad0", arrival_time=0.0)
    eng.step()                          # ad0 pinned for a long time
    rb = eng.submit(prompt_of(24, seed=1)
                    + list(specs[1].invocation_tokens), 2,
                    adapter_name="ad1", arrival_time=1e-9)
    for k in range(6):                  # a stream of resident-adapter
        eng.submit(prompt_of(24, seed=2 + k)             # overtakers
                   + list(specs[0].invocation_tokens), 2,
                   adapter_name="ad0", arrival_time=1e-9 * (2 + k))
    admit_order, seen = [], set()
    for _ in range(500):
        if not (eng.pending or eng.waiting or eng.running):
            break
        eng.step()
        for r in eng.running:
            if r.req_id not in seen:
                seen.add(r.req_id)
                admit_order.append(r.req_id)
        # the property: the cap bounds every queued request's bypasses
        assert all(q.admission_skips <= cap for q in eng.waiting)
    else:
        raise AssertionError("engine did not drain")
    # exactly `cap` younger admissions overtook rb, then it barriered:
    # nothing younger admitted until rb itself got its slot
    assert admit_order.index(rb) == admit_order.index(hold) + 1 + cap
    assert eng.request(rb).admission_skips == cap
    assert len(eng.request(rb).output_tokens) == 2
    assert eng.adapter_pool.pinned_slots() == 0


def test_affinity_churn_with_preemption_matches_oracle(eng_setup):
    """Adapter churn + recompute-preemption under affinity reordering:
    a KV pool too small for the working set forces preemptions while
    slots cycle; tokens must still match the all-resident sequential
    oracle and every pin and stage must drain."""
    cfg, params, specs, weights = eng_setup
    ads = list(zip(specs, weights))

    def workload(eng, gen=4):
        # 61 + 3 invocation tokens = 64 = exactly 4 blocks: the first
        # decode token then needs a 5th block -> guaranteed starvation
        # at num_blocks=8 with two requests running
        rids = [eng.submit(prompt_of(61, seed=k)
                           + list(s.invocation_tokens), gen,
                           adapter_name=s.name, arrival_time=1e-9 * k)
                for k, s in enumerate(specs)]
        eng.run_until_idle()
        return [eng.request(r).output_tokens for r in rids]

    eng_o = Engine(cfg, params, adapters=ads,
                   engine_cfg=EngineConfig(execution_mode="sequential",
                                           max_running=2))
    oracle = workload(eng_o)
    assert eng_o.adapter_pool.evictions == 0     # oracle: all resident

    eng = Engine(cfg, params, adapters=ads,
                 engine_cfg=EngineConfig(adapter_slots=2, max_running=2,
                                         num_blocks=8))
    out = workload(eng)
    assert out == oracle
    assert eng.preemptions > 0                   # pool actually starved
    assert eng.adapter_pool.evictions > 0        # slots actually cycled
    assert eng.adapter_pool.pinned_slots() == 0
    assert eng.adapter_pool.staged_now == 0
    assert eng.kv_mgr.num_free() == eng.ecfg.num_blocks


def test_failed_admission_never_wastes_an_install(setup, monkeypatch):
    """The adapter slot is charged AFTER block allocation: a KV-side
    admission failure must leave the pool completely untouched — no
    pin, no install, no eviction paid for a request that can't run."""
    from repro.core.kv_manager import OutOfBlocks
    cfg, params = setup
    s = AdapterSpec("ad", rank=8)
    eng = Engine(cfg, params, adapters=[(s, mk_weights(cfg, 80))],
                 engine_cfg=EngineConfig(num_blocks=32))
    monkeypatch.setattr(eng.kv_mgr, "allocate",
                        lambda: (_ for _ in ()).throw(
                            OutOfBlocks("injected")))
    rid = eng.submit(prompt_of(48, seed=1), 2, adapter_name="ad")
    assert not eng._try_admit(eng.request(rid))
    monkeypatch.undo()
    pool = eng.adapter_pool
    assert pool.pinned_slots() == 0
    assert pool.installs == 0                    # never touched
    assert eng.request(rid).adapter_slot == 0
    eng.run_until_idle()
    assert len(eng.request(rid).output_tokens) == 2


def test_adapter_slot_failure_rolls_back_blocks(setup):
    """The converse path: blocks were allocated, then the adapter slot
    could not be pinned — everything block-side must be released."""
    cfg, params = setup
    sa, sb = AdapterSpec("A", rank=8), AdapterSpec("B", rank=8)
    eng = Engine(cfg, params,
                 adapters=[(sa, mk_weights(cfg, 81)),
                           (sb, mk_weights(cfg, 82))],
                 engine_cfg=EngineConfig(adapter_slots=1, max_running=4))
    ra = eng.submit(prompt_of(32, seed=1), 8, adapter_name="A")
    eng.step()                                   # A admitted, slot pinned
    free_before = eng.kv_mgr.num_free()
    rb = eng.submit(prompt_of(32, seed=2), 2, adapter_name="B")
    assert not eng._try_admit(eng.request(rb))   # no unpinned slot
    assert eng.kv_mgr.num_free() == free_before  # blocks rolled back
    assert eng.request(rb).block_ids == []
    eng.run_until_idle()                         # B runs once A finishes
    assert len(eng.request(rb).output_tokens) == 2
    assert len(eng.request(ra).output_tokens) == 8
