"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in interpret
mode (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (alora_qkv_op, paged_attention_op,
                               ragged_lora_op)
from repro.kernels.ragged_lora import ragged_grouped_lora_ref
from repro.kernels.ref import alora_qkv_ref, paged_attention_ref
from repro.models.layers import lora_delta

KEY = jax.random.key(0)


@pytest.mark.parametrize("T,d,out,n,r", [
    (64, 32, 48, 2, 4),
    (100, 64, 96, 3, 8),        # padding path
    (7, 32, 48, 4, 16),         # tiny T
    (256, 128, 256, 1, 4),      # zero-adapter-only stack
    (33, 48, 64, 5, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_alora_qkv_sweep(T, d, out, n, r, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (T, d)).astype(dtype)
    w = (jax.random.normal(ks[1], (d, out)) * 0.1).astype(dtype)
    a = (jax.random.normal(ks[2], (n, d, r)) * 0.1).astype(dtype)
    a = a.at[0].set(0.0)
    b = (jax.random.normal(ks[3], (n, r, out)) * 0.1).astype(dtype)
    idx = jax.random.randint(ks[4], (T,), 0, n)
    got = alora_qkv_op(x, w, a, b, idx, interpret=True)
    want = alora_qkv_ref(x, w, a, b, idx)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_alora_qkv_mask_semantics():
    """Kernel applies the adapter ONLY at post-activation tokens."""
    T, d, out, r = 32, 16, 24, 4
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (T, d))
    w = jax.random.normal(ks[1], (d, out)) * 0.1
    a = jnp.concatenate([jnp.zeros((1, d, r)),
                         jax.random.normal(ks[2], (1, d, r))])
    b = jax.random.normal(ks[3], (2, r, out))
    inv = 10
    idx = jnp.where(jnp.arange(T) >= inv, 1, 0)
    got = alora_qkv_op(x, w, a, b, idx, interpret=True)
    base = x @ w
    np.testing.assert_allclose(np.asarray(got[:inv]),
                               np.asarray(base[:inv]),
                               rtol=1e-5, atol=1e-5)
    assert np.abs(np.asarray(got[inv:] - base[inv:])).max() > 0


@pytest.mark.parametrize("B,H,KV,hd,NB,bs,nb,window", [
    (3, 8, 2, 32, 16, 8, 4, 0),
    (2, 4, 4, 16, 8, 4, 2, 8),       # MHA + window
    (1, 16, 2, 64, 32, 16, 8, 0),    # GQA 8:1
    (4, 4, 1, 8, 8, 4, 4, 0),        # single kv head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, H, KV, hd, NB, bs, nb, window, dtype):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dtype)
    kp = jax.random.normal(ks[1], (NB, bs, KV, hd)).astype(dtype)
    vp = jax.random.normal(ks[2], (NB, bs, KV, hd)).astype(dtype)
    bt = jax.random.randint(ks[3], (B, nb), 0, NB)
    ln = jax.random.randint(ks[4], (B,), 1, nb * bs + 1)
    got = paged_attention_op(q, kp, vp, bt, ln, window=window,
                             interpret=True)
    want = paged_attention_ref(q, kp, vp, bt, ln, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("T,R,H,KV,hd,NB,bs,nb,window", [
    (6, 3, 8, 2, 32, 16, 8, 4, 0),     # mixed tokens-per-request
    (5, 2, 4, 4, 16, 8, 4, 2, 8),      # MHA + window
    (9, 4, 4, 1, 8, 8, 4, 4, 0),       # single kv head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_paged_attention_sweep(T, R, H, KV, hd, NB, bs, nb,
                                      window, dtype):
    """Mixed-batch kernel vs jnp oracle: tokens of several requests with
    ragged causal lengths in one launch."""
    from repro.kernels.ops import (ragged_paged_attention_op,
                                   ragged_paged_attention_ref)
    ks = jax.random.split(KEY, 6)
    q = jax.random.normal(ks[0], (T, H, hd)).astype(dtype)
    kp = jax.random.normal(ks[1], (NB, bs, KV, hd)).astype(dtype)
    vp = jax.random.normal(ks[2], (NB, bs, KV, hd)).astype(dtype)
    bt = jax.random.randint(ks[3], (R, nb), 0, NB)
    rows = jax.random.randint(ks[4], (T,), 0, R)
    ln = jax.random.randint(ks[5], (T,), 1, nb * bs + 1)
    got = ragged_paged_attention_op(q, kp, vp, bt, rows, ln,
                                    window=window, interpret=True)
    want = ragged_paged_attention_ref(q, kp, vp, bt, rows, ln,
                                      window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_ragged_matches_paged_on_decode_batch():
    """With one token per request the ragged path degenerates to plain
    paged decode attention — both oracles must agree exactly."""
    from repro.kernels.ops import ragged_paged_attention_ref
    B, H, KV, hd, NB, bs, nb = 3, 8, 2, 32, 16, 8, 4
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (NB, bs, KV, hd))
    vp = jax.random.normal(ks[2], (NB, bs, KV, hd))
    bt = jax.random.randint(ks[3], (B, nb), 0, NB)
    ln = jax.random.randint(ks[4], (B,), 1, nb * bs + 1)
    got = ragged_paged_attention_ref(q, kp, vp, bt, jnp.arange(B), ln)
    want = paged_attention_ref(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_paged_attention_ignores_padding_blocks():
    """Entries of the block table beyond `lengths` must not matter."""
    B, H, KV, hd, NB, bs, nb = 1, 4, 2, 16, 8, 4, 4
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (NB, bs, KV, hd))
    vp = jax.random.normal(ks[2], (NB, bs, KV, hd))
    ln = jnp.array([6])                        # 1.5 blocks valid
    bt1 = jnp.array([[0, 1, 2, 3]])
    bt2 = jnp.array([[0, 1, 7, 7]])            # different padding blocks
    o1 = paged_attention_op(q, kp, vp, bt1, ln, interpret=True)
    o2 = paged_attention_op(q, kp, vp, bt2, ln, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# ragged grouped-LoRA (SGMV-style, per-token slot indices)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("T,d,out,S,r,K", [
    (64, 32, 48, 4, 8, 2),
    (100, 64, 96, 6, 8, 4),       # padding path
    (7, 32, 48, 3, 16, 2),        # tiny T
    (33, 48, 64, 8, 32, 8),       # every slot active
    (50, 32, 40, 4, 8, 1),        # single active slot
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_lora_sweep(T, d, out, S, r, K, dtype):
    """Pallas grouped kernel vs jnp ref across shapes/dtypes; tokens
    reference only a K-sized subset of the S+1 slot stack."""
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (T, d)).astype(dtype)
    a = (jax.random.normal(ks[1], (S + 1, d, r)) * 0.1).astype(dtype)
    a = a.at[0].set(0.0)                       # slot 0: zero adapter
    b = (jax.random.normal(ks[2], (S + 1, r, out)) * 0.1).astype(dtype)
    active = np.sort(np.random.RandomState(T).choice(
        np.arange(1, S + 1), K, replace=False)).astype(np.int32)
    Kb = 1 << (K - 1).bit_length() if K > 1 else 1
    act = jnp.asarray(np.pad(active, (0, Kb - K)))   # pow2, 0-padded
    choices = np.concatenate([[0], active])
    idx = jnp.asarray(np.random.RandomState(T + 1).choice(choices, T),
                      jnp.int32)
    got = ragged_lora_op(x, a, b, idx, act, interpret=True)
    want = ragged_grouped_lora_ref(x, a, b, idx, act)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_ragged_lora_ref_matches_dense_scan_bitwise():
    """The grouped ref sums active slots in ascending order; inactive
    slots of the dense scan contribute exact zeros — the two must agree
    BITWISE (this is what keeps mixed_lora_impl=ref token-identical to
    the dense oracle)."""
    T, d, out, S, r = 40, 32, 48, 6, 8
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (T, d))
    a = (jax.random.normal(ks[1], (S + 1, d, r)) * 0.1).at[0].set(0.0)
    b = jax.random.normal(ks[2], (S + 1, r, out)) * 0.1
    idx = jnp.asarray(np.random.RandomState(3).choice([0, 2, 5], T),
                      jnp.int32)
    act = jnp.asarray([2, 5, 0, 0], jnp.int32)
    dense = lora_delta(x, a, b, idx)
    grouped = ragged_grouped_lora_ref(x, a, b, idx, act)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(grouped))


def test_ragged_lora_inactive_slots_do_not_leak():
    """Slots resident in the stack but absent from active_slots must not
    contribute even for tokens (erroneously) indexing them — the grouped
    delta only ever reads the active set."""
    T, d, out, S, r = 16, 24, 32, 4, 8
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (T, d))
    a = (jax.random.normal(ks[1], (S + 1, d, r))).at[0].set(0.0)
    b = jax.random.normal(ks[2], (S + 1, r, out))
    idx = jnp.full((T,), 3, jnp.int32)         # tokens point at slot 3
    act = jnp.asarray([1, 0], jnp.int32)       # ...but only 1 is active
    got = ragged_lora_op(x, a, b, idx, act, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.zeros((T, out),
                                                            np.float32))
