"""Tier-1 coverage for the invariant analyzer (``repro.analysis``):

* ``parse_collectives`` / byte-accounting satellites (tuple results,
  -start/-done dedup, fractional s4 widths, round-at-the-edge);
* the ``d2h_fetches`` ring-buffer trim;
* every Pass-A check against synthetic HLO snippets, firing and not;
* every Pass-B lint rule against AST fixtures, firing and not;
* every Pass-C lifecycle rule against AST fixtures — one per historical
  leak (admission rollback, encoder-KV, OutOfBlocks claim, staging,
  prefetch-window collapse), each flagged pre-fix and clean as fixed;
* the B5 phase protocol (retire-only mutations unreachable from
  schedule/submit without an annotated sanction);
* the real tree lints AND lifecycle-checks clean, the real goldens are
  checked in for every config × mesh, and one real compiled-step audit
  passes end to end;
* the CLI's exit-code contract.
"""
import json
import os

import jax
import jax.numpy as jnp

from repro.analysis.hotpath_lint import lint_files, lint_tree
from repro.analysis.lifecycle_check import check_files, check_tree
from repro.analysis.step_audit import (
    MESHES,
    check_bf16_upcasts,
    check_donation,
    check_dynamic_shapes,
    check_host_callbacks,
    check_payload,
    diff_fingerprint,
    entry_body,
    golden_path,
    parse_aliases,
)
from repro.launch.hlo_analysis import CollectiveStats, _shape_bytes, parse_collectives
from repro.serving.runner import D2H_LOG_KEEP, D2H_LOG_MAX, log_d2h, next_pow2

SRC_ROOT = os.path.join(os.path.dirname(__file__), "..", "src")


# ------------------------------------------- satellite: parse_collectives
def test_parse_collectives_scalar_shape():
    s = parse_collectives(
        "%ar = f32[128]{0} all-reduce(%x), replica_groups={}\n")
    assert s.counts == {"all-reduce": 1}
    assert s.by_kind == {"all-reduce": 512.0}
    assert s.total_result_bytes() == 512


def test_parse_collectives_tuple_shape():
    s = parse_collectives(
        "%ar = (f32[128]{0}, bf16[64]{0}) all-reduce(%a, %b)\n")
    assert s.counts == {"all-reduce": 1}
    assert s.by_kind["all-reduce"] == 128 * 4 + 64 * 2


def test_parse_collectives_start_done_dedup():
    txt = ("%ag-s = bf16[2,64]{1,0} all-gather-start(%x)\n"
           "%ag-d = bf16[2,64]{1,0} all-gather-done(%ag-s)\n")
    s = parse_collectives(txt)
    assert s.counts == {"all-gather": 1}
    assert s.by_kind["all-gather"] == 2 * 64 * 2


def test_parse_collectives_multi_kind():
    txt = ("%a = f32[128]{0} all-reduce(%x)\n"
           "%b = s8[100]{0} collective-permute(%y)\n"
           "%c = (f32[8]{0}, f32[8]{0}) all-to-all(%u, %v)\n"
           "%d = f32[128]{0} all-reduce(%z)\n")
    s = parse_collectives(txt)
    assert s.counts == {"all-reduce": 2, "collective-permute": 1,
                       "all-to-all": 1}
    assert s.by_kind == {"all-reduce": 1024.0, "collective-permute": 100.0,
                        "all-to-all": 64.0}


def test_sub_byte_dtypes_round_only_at_edge():
    assert _shape_bytes("s4", "1") == 0.5
    assert _shape_bytes("u4", "8") == 4.0
    s = CollectiveStats(by_kind={"all-gather": 0.5, "all-reduce": 1.9})
    assert s.total_result_bytes() == 2      # round(2.4)
    # fractional values survive inside the accounting itself
    assert s.by_kind["all-gather"] == 0.5


# ------------------------------------------------ satellite: d2h ring trim
def test_d2h_log_ring_buffer_trims_keeping_recent():
    log = []
    n = D2H_LOG_MAX + 100
    for i in range(n):
        log_d2h(log, i, "int32", "step")
    assert len(log) < D2H_LOG_MAX
    elems = [e for e, _, _ in log]
    # most recent entries, in order, contiguous
    assert elems == list(range(n - len(log), n))
    assert log[-1] == (n - 1, "int32", "step")
    # trim fired exactly when full: kept KEEP then kept appending
    assert len(log) == D2H_LOG_KEEP + (n - D2H_LOG_MAX)


# --------------------------------------------------- Pass A: HLO checks
CLEAN_HLO = """\
HloModule step, input_output_alias={ {0}: (3, {}, may-alias), {1}: (4, {}, may-alias) }

%fused (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  ROOT %n = f32[4]{0} negate(%a)
}

ENTRY %main (p0: f32[4], p1: f32[4]) -> (f32[4], f32[4], s32[4]) {
  %p0 = f32[4]{0} parameter(0)
  %p1 = f32[4]{0} parameter(1)
  %s = s32[4]{0} constant({0, 1, 2, 3})
  ROOT %t = (f32[4]{0}, f32[4]{0}, s32[4]{0}) tuple(%p0, %p1, %s)
}
"""


def test_host_callback_clean_and_firing():
    assert check_host_callbacks(CLEAN_HLO) == []
    bad = CLEAN_HLO.replace(
        "negate(%a)", 'custom-call(%a), custom_call_target="my_cb"')
    vs = check_host_callbacks(bad)
    assert len(vs) == 1 and "my_cb" in vs[0]
    # allowlisted device-side custom calls (XLA's TopK expansion, from
    # the MoE router) are not host callbacks
    topk = CLEAN_HLO.replace(
        "negate(%a)", 'custom-call(%a), custom_call_target="TopK"')
    assert check_host_callbacks(topk) == []
    assert any("infeed" in v for v in check_host_callbacks(
        CLEAN_HLO + "  %i = token[] infeed(%tok)\n"))


def test_dynamic_shape_markers():
    assert check_dynamic_shapes(CLEAN_HLO) == []
    assert check_dynamic_shapes(
        CLEAN_HLO.replace("f32[4]{0} negate", "f32[<=4]{0} negate"))


def test_bf16_upcast_inline_and_defmap():
    inline = "%c = f32[64,64]{1,0} convert(bf16[64,64]{1,0} %w)\n"
    assert check_bf16_upcasts(inline, threshold_elems=64 * 64)
    # below the param-size threshold: activations may upcast
    assert check_bf16_upcasts(inline, threshold_elems=64 * 64 + 1) == []
    defmap = ("%w = bf16[64,64]{1,0} parameter(0)\n"
              "%c = f32[64,64]{1,0} convert(%w)\n")
    assert check_bf16_upcasts(defmap, threshold_elems=64 * 64)
    # f32 source: not an upcast of bf16
    f32src = "%c = f32[64,64]{1,0} convert(s32[64,64]{1,0} %w)\n"
    assert check_bf16_upcasts(f32src, threshold_elems=1) == []


def test_parse_aliases_and_entry_body():
    assert parse_aliases(CLEAN_HLO) == {0: 3, 1: 4}
    assert parse_aliases("HloModule step\nENTRY %m {\n}\n") == {}
    body = entry_body(CLEAN_HLO)
    # the inner computation's ROOT must not leak into the entry body
    assert "negate" not in body and "tuple(" in body


def _leaf(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


class _Cfg:
    vocab_size = 512


GOOD_LEAVES = [
    ("k_pool", _leaf((2, 8, 4), jnp.float32)),
    ("v_pool", _leaf((2, 8, 4), jnp.float32)),
    ("tok_buf", _leaf((8,), jnp.int32)),
    ("b_ssm", _leaf((), jnp.int32)),
    ("b_conv", _leaf((), jnp.int32)),
    ("sampled", _leaf((8,), jnp.int32)),
]
GOOD_ALIASES = {0: 3, 1: 4, 2: 7}


def test_payload_clean():
    assert check_payload(GOOD_LEAVES, GOOD_ALIASES, _Cfg(), 5) == []


def test_payload_rejects_undonated_pool_and_vocab_and_sampled():
    # pool falls out of the alias map -> it became host payload
    vs = check_payload(GOOD_LEAVES, {1: 4, 2: 7}, _Cfg(), 5)
    assert any("k_pool" in v and "ids-only" in v for v in vs)
    # (R, vocab) logits-shaped host output
    leaves = GOOD_LEAVES[:-1] + [("sampled", _leaf((8,), jnp.int32)),
                                 ("b_ssm", _leaf((4, 512), jnp.float32))]
    assert any("vocab" in v for v in check_payload(
        leaves, GOOD_ALIASES, _Cfg(), 5))
    # sampled must be small 1-D s32
    bad = GOOD_LEAVES[:-1] + [("sampled", _leaf((8, 2), jnp.float32))]
    assert any("sampled" in v for v in check_payload(
        bad, GOOD_ALIASES, _Cfg(), 5))
    big = GOOD_LEAVES[:-1] + [("sampled",
                               _leaf((2 * next_pow2(5),), jnp.int32))]
    assert any("sampled" in v for v in check_payload(
        big, GOOD_ALIASES, _Cfg(), 5))


def test_donation_clean_and_firing():
    vs, donated = check_donation(GOOD_LEAVES, GOOD_ALIASES, has_ssm=False)
    assert vs == [] and donated == ["k_pool", "tok_buf", "v_pool"]
    # missing pool alias
    vs, _ = check_donation(GOOD_LEAVES, {0: 3, 1: 4}, has_ssm=False)
    assert any("tok_buf" in v and "not in input_output_alias" in v
               for v in vs)
    # alias of a non-pool output
    vs, _ = check_donation(GOOD_LEAVES, {**GOOD_ALIASES, 5: 9},
                           has_ssm=False)
    assert any("unexpected alias" in v for v in vs)
    # SSM arch must emit + donate its live pools
    vs, _ = check_donation(GOOD_LEAVES, GOOD_ALIASES, has_ssm=True)
    assert any("live_ssm" in v and "absent" in v for v in vs)


def test_fingerprint_diff():
    fp = {"counts": {"all-reduce": 9}, "result_bytes": {"all-reduce": 512}}
    assert diff_fingerprint("a", "1x1", fp, fp) == ""
    drift = {"counts": {"all-reduce": 10},
             "result_bytes": {"all-reduce": 512}}
    d = diff_fingerprint("a", "2x4", fp, drift)
    assert "all-reduce" in d and "drift" in d
    # per-op grouping names the golden -> seen count delta and the
    # likely config knob
    assert "10 -> 9 (-1)" in d and "likely knob" in d
    assert "model" in d          # all-reduce drift -> model-axis knob
    new_op = {"counts": {"all-reduce": 9, "all-gather": 2},
              "result_bytes": {"all-reduce": 512, "all-gather": 64}}
    d2 = diff_fingerprint("a", "2x4", new_op, fp)
    assert "NEW op" in d2 and "all-gather" in d2
    assert "no golden" in diff_fingerprint("a", "2x4", fp, None)


# --------------------------------------------------- Pass B: lint fixtures
FIXTURE_KW = dict(roots=(("Engine", "step"),),
                  retire={("Engine", "_retire")}, oracle=set(),
                  retire_only=set(),
                  attr_classes={"runner": "ModelRunner"})

GOOD_SRC = '''\
import numpy as np

def log_d2h(log, elems, dtype, tag):
    log.append((elems, dtype, tag))

class ModelRunner:
    def fetch(self, h):
        x = np.asarray(h)  # hotpath: sync-ok (test fixture)
        log_d2h([], 1, "int32", "step")
        return x

class Engine:
    def step(self):
        self._schedule()
        self.runner.fetch(None)
        self._retire()

    def _schedule(self):
        return np.array([1, 2])

    def _retire(self):
        return np.asarray([1]).item()

    def _never_called(self):
        return np.asarray([2])
'''


def _write(tmp_path, name, src):
    p = tmp_path / name
    p.write_text(src)
    return str(p)


def test_lint_fixture_clean(tmp_path):
    vs = lint_files([_write(tmp_path, "good.py", GOOD_SRC)],
                    **FIXTURE_KW)
    assert vs == []


def test_lint_hot_sync_fires(tmp_path):
    src = GOOD_SRC.replace("return np.array([1, 2])",
                           "return np.asarray([1, 2])")
    vs = lint_files([_write(tmp_path, "bad.py", src)], **FIXTURE_KW)
    assert [v.rule for v in vs] == ["hot-sync"]
    assert "_schedule" in vs[0].message


def test_lint_item_and_device_get_and_block(tmp_path):
    src = GOOD_SRC.replace(
        "return np.array([1, 2])",
        "import jax\n"
        "        jax.device_get(1)\n"
        "        x = np.float32(3); x.item()\n"
        "        return x.block_until_ready()")
    vs = lint_files([_write(tmp_path, "bad.py", src)], **FIXTURE_KW)
    assert sorted(v.rule for v in vs) == ["hot-sync"] * 3


def test_lint_annotated_but_unlogged(tmp_path):
    src = GOOD_SRC.replace('        log_d2h([], 1, "int32", "step")\n',
                           "")
    vs = lint_files([_write(tmp_path, "bad.py", src)], **FIXTURE_KW)
    assert [v.rule for v in vs] == ["sync-unlogged"]


def test_lint_jnp_outside_jit(tmp_path):
    src = GOOD_SRC.replace(
        "return np.array([1, 2])",
        "import jax.numpy as jnp\n"
        "        jnp.asarray([1])\n"          # allowlisted: H2D staging
        "        return jnp.zeros((2,))")     # eager dispatch: fires
    vs = lint_files([_write(tmp_path, "bad.py", src)], **FIXTURE_KW)
    assert [v.rule for v in vs] == ["jnp-outside-jit"]
    assert "zeros" in vs[0].message


def test_lint_jnp_inside_jit_allowed(tmp_path):
    src = GOOD_SRC + '''
import jax
import jax.numpy as jnp
from functools import partial

@partial(jax.jit, static_argnums=0)
def _impl(n, x):
    return jnp.zeros((n,)) + x
'''
    assert lint_files([_write(tmp_path, "f.py", src)],
                      **FIXTURE_KW) == []


def test_lint_time_in_jit(tmp_path):
    src = GOOD_SRC + '''
import time
import jax
from functools import partial

def fine():
    return time.time()

@partial(jax.jit, static_argnums=0)
def _impl(n, x):
    return x * time.time()
'''
    vs = lint_files([_write(tmp_path, "f.py", src)], **FIXTURE_KW)
    assert [v.rule for v in vs] == ["time-in-jit"]
    assert "_impl" in vs[0].message


def test_lint_phase_table_honesty(tmp_path):
    vs = lint_files([_write(tmp_path, "good.py", GOOD_SRC)],
                    roots=(("Engine", "step"),),
                    retire={("Engine", "_retire"),
                            ("Engine", "_gone_with_refactor")},
                    oracle=set(), retire_only=set(),
                    attr_classes={"runner": "ModelRunner"})
    assert [v.rule for v in vs] == ["phase-table"]
    assert "_gone_with_refactor" in vs[0].message


def test_lint_obs_clean_tracer_passes(tmp_path):
    """Plain-python append-only recording (the Tracer shape) passes the
    wholesale B4 check — os/time/dict/list work is exactly what the
    hot path may do."""
    obs = ("import time\n"
           "class Tracer:\n"
           "    def event(self, track, name):\n"
           "        t = time.perf_counter()\n"
           "        self.events.append((track, name, t))\n")
    vs = lint_files([_write(tmp_path, "good.py", GOOD_SRC)],
                    obs_paths=(_write(tmp_path, "tracer.py", obs),),
                    **FIXTURE_KW)
    assert vs == []


def test_lint_obs_jax_and_sync_fire_without_annotation_escape(tmp_path):
    """ANY jax/jnp call or blocking construct in trace-recording code
    fires B4 — even unreachable from the roots, and even on a line
    carrying the ``# hotpath: sync-ok`` annotation (no escape hatch in
    obs files)."""
    obs = ("import jax.numpy as jnp\n"
           "class Tracer:\n"
           "    def event(self, x):\n"
           "        v = jnp.asarray(x)  # hotpath: sync-ok\n"
           "        v.block_until_ready()  # hotpath: sync-ok\n"
           "        self.events.append(v)\n")
    vs = lint_files([_write(tmp_path, "good.py", GOOD_SRC)],
                    obs_paths=(_write(tmp_path, "tracer.py", obs),),
                    **FIXTURE_KW)
    assert sorted(v.rule for v in vs) == ["obs-jax", "obs-sync"]
    assert all("Tracer.event" in v.message for v in vs)


def test_lint_kernels_checked_even_unreachable(tmp_path):
    kernel = ("import numpy as np\n"
              "def _kernel_body(x):\n"
              "    return np.asarray(x)\n")
    vs = lint_files([_write(tmp_path, "good.py", GOOD_SRC)],
                    kernel_paths=(_write(tmp_path, "k.py", kernel),),
                    **FIXTURE_KW)
    assert [v.rule for v in vs] == ["hot-sync"]
    assert "_kernel_body" in vs[0].message


# ------------------------------------ Pass C: resource-lifecycle fixtures
#
# One fixture pair per historical leak: the pre-fix shape (Pass C must
# flag it) and the shipped fix (must analyze clean).  ``teardown={}``
# disables the real teardown-coverage table so fixtures aren't required
# to define Engine._preempt / _finish_requests.

def _lc(tmp_path, src, teardown=None):
    return check_files([_write(tmp_path, "fix.py", src)],
                       teardown=teardown if teardown is not None else {})


# historical leak 1: admission rollback — OutOfBlocks mid-claim returned
# without releasing the cache-matched blocks or the speculative state
# slot (pre-PR2 shape)
LC_ROLLBACK_LEAK = '''\
class Engine:
    def _try_admit(self, req):
        m = self.cache.match_and_acquire(req.prompt)
        n, kv_blocks, state_slot = m.n_tokens, m.kv_blocks, m.state_slot
        new_blocks = []
        try:
            for _ in range(3):
                new_blocks.append(self.kv_mgr.allocate())
        except OutOfBlocks:
            return False
        req.block_ids = kv_blocks + new_blocks
        return True
'''

LC_ROLLBACK_FIXED = '''\
class Engine:
    def _try_admit(self, req):
        m = self.cache.match_and_acquire(req.prompt)
        n, kv_blocks, state_slot = m.n_tokens, m.kv_blocks, m.state_slot
        new_blocks = []
        def bail():
            if self.kv_mgr is not None:
                self.kv_mgr.release_all(kv_blocks + new_blocks)
            if state_slot is not None:
                self.st_mgr.release(state_slot)
            return False
        try:
            for _ in range(3):
                new_blocks.append(self.kv_mgr.allocate())
        except OutOfBlocks:
            return bail()
        req.block_ids = kv_blocks + new_blocks
        if state_slot is not None:
            self.st_mgr.release(state_slot)
        return True
'''


def test_lc_rollback_leak_and_fix(tmp_path):
    vs = _lc(tmp_path, LC_ROLLBACK_LEAK)
    assert vs and set(v.rule for v in vs) == {"leak"}
    # both the matched KV blocks and the optional state slot leak
    assert any("kv" in v.message for v in vs)
    assert _lc(tmp_path, LC_ROLLBACK_FIXED) == []


# historical leak 2: encoder-KV stacks survived preemption — the
# teardown released KV blocks, the run slot and the adapter pin but
# forgot the _xkv entry
LC_TEARDOWN_NO_XKV = '''\
class Engine:
    def _preempt(self, r):
        self.kv_mgr.release_all(r.block_ids)
        self._free_slots.append(r.run_slot)
        self.adapter_pool.release(r.adapter_uid)
'''

LC_TEARDOWN_FIXED = LC_TEARDOWN_NO_XKV.replace(
    "        self.adapter_pool.release(r.adapter_uid)\n",
    "        self.adapter_pool.release(r.adapter_uid)\n"
    "        self._xkv.pop(r.req_id, None)\n")

LC_TEARDOWN_TABLE = {("Engine", "_preempt"):
                     frozenset({"kv", "runslot", "adapter", "xkv"})}


def test_lc_teardown_coverage(tmp_path):
    vs = _lc(tmp_path, LC_TEARDOWN_NO_XKV, teardown=LC_TEARDOWN_TABLE)
    assert [v.rule for v in vs] == ["teardown-missing"]
    assert "xkv" in vs[0].message
    assert _lc(tmp_path, LC_TEARDOWN_FIXED,
               teardown=LC_TEARDOWN_TABLE) == []
    # table honesty: a teardown entry naming a function the tree no
    # longer defines is itself a violation
    gone = {("Engine", "_gone"): frozenset({"kv"})}
    vs = _lc(tmp_path, LC_TEARDOWN_FIXED, teardown=gone)
    assert any(v.rule == "lifecycle-table" for v in vs)


# historical leak 3: speculative decode-block claim — blocks claimed
# into a local list, then `continue` on OutOfBlocks dropped them
LC_CLAIM_LEAK = '''\
class Engine:
    def _schedule_decodes(self):
        for r in self.running:
            claimed = []
            try:
                while r.needs_more():
                    claimed.append(self.kv_mgr.allocate())
            except OutOfBlocks:
                continue
            r.block_ids.extend(claimed)
'''

LC_CLAIM_FIXED = '''\
class Engine:
    def _schedule_decodes(self):
        ok = []
        for r in self.running:
            n_before = len(r.block_ids)
            try:
                while r.needs_more():
                    r.block_ids.append(self.kv_mgr.allocate())
            except OutOfBlocks:
                pass
            if r.still_needs():
                while len(r.block_ids) > n_before:
                    self.kv_mgr.release(r.block_ids.pop())
                continue
            ok.append(r)
        return ok
'''


def test_lc_claim_leak_and_fix(tmp_path):
    vs = _lc(tmp_path, LC_CLAIM_LEAK)
    assert vs and set(v.rule for v in vs) == {"leak"}
    assert _lc(tmp_path, LC_CLAIM_FIXED) == []


# historical leak 4: staged weights pinned without registration — the
# device copy landed on reg.device_layers but never entered _staged, so
# no TTL expiry could ever free it
LC_STAGING_LEAK = '''\
class AdapterPool:
    def prefetch(self, uid):
        reg = self._by_uid[uid]
        reg.device_layers = [self._put(lw) for lw in reg.layers]
        return True
'''

LC_STAGING_FIXED = '''\
class AdapterPool:
    def _stage(self, reg):
        reg.device_layers = [self._put(lw) for lw in reg.layers]
        self._staged[reg.uid] = self._tick
'''


def test_lc_staging_leak_and_fix(tmp_path):
    vs = _lc(tmp_path, LC_STAGING_LEAK)
    assert vs and set(v.rule for v in vs) == {"leak"}
    assert any("staged" in v.message for v in vs)
    assert _lc(tmp_path, LC_STAGING_FIXED) == []


# historical leak 5: prefetch-window collapse — bounding the prefetch
# scan by `max_running - len(running)` makes the window shrink to zero
# exactly when the engine is busiest, starving the staging tier
LC_WINDOW_COLLAPSE = '''\
from itertools import islice
class Engine:
    def step(self):
        for uid in islice(self.pending,
                          self.max_running - len(self.running)):
            self.adapter_pool.prefetch(uid)
'''

LC_WINDOW_FIXED = '''\
from itertools import islice
class Engine:
    def step(self):
        for r in islice(self.waiting, self.ecfg.admission_window):
            self.adapter_pool.prefetch(r.adapter_uid)
'''


def test_lc_window_collapse_and_fix(tmp_path):
    vs = _lc(tmp_path, LC_WINDOW_COLLAPSE)
    assert [v.rule for v in vs] == ["window-collapse"]
    assert _lc(tmp_path, LC_WINDOW_FIXED) == []


# ------------------------------------------ Pass C: rule-level fixtures
def test_lc_plain_leak_at_early_return(tmp_path):
    src = ('class Engine:\n'
           '    def f(self):\n'
           '        b = self.kv_mgr.allocate()\n'
           '        if self.bad:\n'
           '            return False\n'
           '        self.kv_mgr.release(b)\n'
           '        return True\n')
    vs = _lc(tmp_path, src)
    assert [v.rule for v in vs] == ["leak"]
    assert "kv" in vs[0].message


def test_lc_adapter_pin_narrowing_and_leak(tmp_path):
    clean = ('class Engine:\n'
             '    def f(self, req):\n'
             '        slot = self.adapter_pool.acquire(req.adapter_uid)\n'
             '        if slot is None:\n'
             '            return False\n'
             '        req.adapter_slot = slot\n'
             '        return True\n')
    assert _lc(tmp_path, clean) == []
    leak = clean.replace(
        "        req.adapter_slot = slot\n",
        "        if req.too_big:\n"
        "            return False\n"
        "        req.adapter_slot = slot\n")
    vs = _lc(tmp_path, leak)
    assert [v.rule for v in vs] == ["leak"]
    assert "adapter" in vs[0].message


def test_lc_owner_annotation_and_honesty(tmp_path):
    ann = ('class Engine:\n'
           '    def f(self):\n'
           '        b = self.kv_mgr.allocate()   # owner: self._ledger\n'
           '        self._ledger.note(b)\n')
    assert _lc(tmp_path, ann) == []
    stale = ('class Engine:\n'
             '    # owner: nothing acquired here\n'
             '    def f(self):\n'
             '        return 1\n')
    vs = _lc(tmp_path, stale)
    assert [v.rule for v in vs] == ["owner-unused"]


# --------------------------------------- B5: phase-protocol fixtures
B5_KW = dict(roots=(("Engine", "step"),), retire=set(), oracle=set(),
             retire_only={("Engine", "_finish")}, attr_classes={})

B5_SRC = '''\
import numpy as np

class Engine:
    def step(self):
        self._schedule()
        self._finish()

    def _schedule(self):
        return np.array([1])

    def _finish(self):
        self.done = []
'''


def test_lint_phase_retire_only_fires(tmp_path):
    vs = lint_files([_write(tmp_path, "b5.py", B5_SRC)], **B5_KW)
    assert [v.rule for v in vs] == ["phase-retire-only"]
    assert "_finish" in vs[0].message


def test_lint_phase_annotation_sanctions(tmp_path):
    src = B5_SRC.replace(
        "        self._finish()\n",
        "        # phase: retire-ok (test fixture sanction)\n"
        "        self._finish()\n")
    assert lint_files([_write(tmp_path, "b5.py", src)], **B5_KW) == []


def test_lint_phase_stale_annotation_fires(tmp_path):
    src = B5_SRC.replace(
        "        self._finish()\n",
        "        # phase: retire-ok (test fixture sanction)\n"
        "        self._finish()\n").replace(
        "        return np.array([1])\n",
        "        # phase: retire-ok (sanctions nothing)\n"
        "        return np.array([1])\n")
    vs = lint_files([_write(tmp_path, "b5.py", src)], **B5_KW)
    assert [v.rule for v in vs] == ["phase-stale"]


def test_lint_retire_only_table_honesty(tmp_path):
    kw = dict(B5_KW, retire_only={("Engine", "_gone")})
    vs = lint_files([_write(tmp_path, "b5.py", B5_SRC)], **kw)
    assert [v.rule for v in vs] == ["phase-table"]
    assert "_gone" in vs[0].message


# ------------------------------------------------------- the real tree
def test_real_tree_lints_clean():
    assert lint_tree(SRC_ROOT) == []


def test_real_tree_lifecycle_clean():
    """The shipped scheduler provably releases or transfers every
    acquire-shaped resource on every exit path."""
    assert check_tree(SRC_ROOT) == []


def test_goldens_checked_in_for_every_config_and_mesh():
    from repro.configs import all_configs
    for arch in sorted(all_configs()):
        for mesh in MESHES:
            p = golden_path(arch, mesh)
            assert os.path.exists(p), f"missing golden {p}"
            with open(p) as f:
                g = json.load(f)
            assert g["arch"] == arch and g["mesh"] == mesh
            assert set(g) >= {"counts", "result_bytes"}
            if mesh == "1x1":
                # single device: no collectives, ever
                assert g["counts"] == {}


def test_real_step_audit_single_device():
    """End-to-end Pass A on one config against the checked-in golden:
    compiles the production mixed step (~40 s)."""
    from repro.analysis.step_audit import audit_config
    res = audit_config("granite-3.2-8b", "1x1")
    assert res.violations == []
    assert res.fingerprint_diff == ""
    assert res.ok
    assert res.sync_async_identical
    assert res.donated == ["k_pool", "tok_buf", "v_pool"]
    assert res.fingerprint["counts"] == {}
    if res.memory:
        # the donated pools dominate: donation saved that much HBM
        assert res.memory["alias_size_bytes"] > 0
        assert res.memory["alias_size_bytes"] <= \
            res.memory["output_size_bytes"]


# ---------------------------------------------------------------- CLI
def test_cli_lint_clean_tree_exit0(capsys):
    from repro.analysis.__main__ import main
    assert main(["--skip-audit"]) == 0
    assert "0 violation(s)" in capsys.readouterr().out


def test_cli_lint_violation_exit1(tmp_path, capsys):
    from repro.analysis.__main__ import main
    bad = GOOD_SRC.replace("return np.array([1, 2])",
                           "return np.asarray([1, 2])")
    rc = main(["--skip-audit", "--lint-paths",
               _write(tmp_path, "bad.py", bad)])
    assert rc == 1
    assert "hot-sync" in capsys.readouterr().err


def test_cli_json_records_clean_tree(tmp_path, capsys):
    """--json appends one ok record per static pass; a clean run never
    leaves a stale lifecycle artifact behind."""
    from repro.analysis.__main__ import main
    (tmp_path / "analysis_lifecycle.txt").write_text("stale\n")
    assert main(["--skip-audit", "--json", "--out", str(tmp_path)]) == 0
    with open(tmp_path / "analysis_audit.jsonl") as f:
        recs = [json.loads(line) for line in f]
    assert [r["kind"] for r in recs] == ["hotpath_lint",
                                         "lifecycle_check"]
    assert all(r["ok"] and r["n_violations"] == 0 for r in recs)
    assert not (tmp_path / "analysis_lifecycle.txt").exists()


def test_cli_lifecycle_violation_exit1_and_artifacts(tmp_path, capsys):
    from repro.analysis.__main__ import main
    leak = ('class Engine:\n'
            '    def f(self):\n'
            '        b = self.kv_mgr.allocate()\n'
            '        if self.bad:\n'
            '            return False\n'
            '        self.kv_mgr.release(b)\n'
            '        return True\n')
    rc = main(["--skip-audit", "--json", "--out", str(tmp_path),
               "--lint-paths", _write(tmp_path, "leak.py", leak)])
    assert rc == 1
    assert "leak" in capsys.readouterr().err
    assert (tmp_path / "analysis_lifecycle.txt").exists()
    with open(tmp_path / "analysis_lifecycle.txt") as f:
        assert "leak" in f.read()
    with open(tmp_path / "analysis_audit.jsonl") as f:
        recs = [json.loads(line) for line in f]
    lc = [r for r in recs if r.get("kind") == "lifecycle_check"]
    assert len(lc) == 1 and not lc[0]["ok"]
    assert lc[0]["n_violations"] >= 1 and lc[0]["violations"]


def test_cli_audit_failure_exit1_and_artifacts(tmp_path, monkeypatch,
                                               capsys):
    """Exit-code + artifact contract of the audit leg, with the compile
    stubbed out (each real rule class is covered above)."""
    import repro.analysis.step_audit as sa
    from repro.analysis.__main__ import main
    from repro.analysis.step_audit import AuditResult

    def fake_audit_all(archs, meshes, update_goldens=False,
                       progress=None):
        bad = AuditResult(arch="granite-3.2-8b", mesh="2x4")
        bad.violations = ["donation: pool output #0 (k_pool) is not in "
                          "input_output_alias"]
        bad.fingerprint_diff = "granite-3.2-8b [2x4]: drift\n"
        return [AuditResult(arch="granite-3.2-8b", mesh="1x1"), bad]

    monkeypatch.setattr(sa, "audit_all", fake_audit_all)
    rc = main(["--skip-lint", "--out", str(tmp_path)])
    assert rc == 1
    err = capsys.readouterr().err
    assert "donation" in err and "drift" in err
    with open(tmp_path / "analysis_audit.jsonl") as f:
        recs = [json.loads(line) for line in f]
    assert [r["ok"] for r in recs] == [True, False]
    assert (tmp_path / "analysis_fingerprint_diff.txt").exists()


def test_cli_audit_ok_exit0(tmp_path, monkeypatch):
    import repro.analysis.step_audit as sa
    from repro.analysis.__main__ import main
    from repro.analysis.step_audit import AuditResult

    monkeypatch.setattr(
        sa, "audit_all",
        lambda *a, **k: [AuditResult(arch="x", mesh="1x1")])
    assert main(["--skip-lint", "--out", str(tmp_path)]) == 0
    assert not (tmp_path / "analysis_fingerprint_diff.txt").exists()
