"""Activation-aware masking metadata (paper Alg. 1 / App. B)."""
import numpy as np

from repro.core.activation_mask import (adapter_index_for_positions,
                                        build_batch_adapter_idx,
                                        find_invocation_start)


class TestFindInvocation:
    def test_basic(self):
        assert find_invocation_start([1, 2, 7, 8, 9, 3], (7, 8, 9)) == 2

    def test_last_occurrence(self):
        toks = [7, 8, 9, 1, 7, 8, 9, 2]
        assert find_invocation_start(toks, (7, 8, 9)) == 4

    def test_absent(self):
        assert find_invocation_start([1, 2, 3], (7, 8)) is None

    def test_at_end(self):
        assert find_invocation_start([1, 2, 7, 8], (7, 8)) == 2

    def test_empty_inv(self):
        assert find_invocation_start([1, 2], ()) is None


class TestAdapterIndex:
    def test_alora_masks_pre_activation(self):
        pos = np.arange(10)
        idx = adapter_index_for_positions(pos, slot=2, kind="alora",
                                          inv_start=4)
        assert list(idx) == [0] * 4 + [2] * 6

    def test_vanilla_lora_everywhere(self):
        pos = np.arange(5)
        idx = adapter_index_for_positions(pos, slot=1, kind="lora",
                                          inv_start=3)
        assert list(idx) == [1] * 5

    def test_base_all_zero(self):
        idx = adapter_index_for_positions(np.arange(5), slot=0, kind=None,
                                          inv_start=0)
        assert list(idx) == [0] * 5

    def test_batch_mixed(self):
        """A batch mixing base / aLoRA / LoRA with varying activation
        points (the paper's heterogeneous-batch case)."""
        rows = [np.arange(4), np.arange(4) + 2, np.arange(4)]
        out = build_batch_adapter_idx(
            rows, slots=[0, 1, 2], kinds=[None, "alora", "lora"],
            inv_starts=[0, 4, 0])
        assert out.shape == (3, 4)
        assert list(out[0]) == [0, 0, 0, 0]
        assert list(out[1]) == [0, 0, 1, 1]     # positions 2,3,4,5 vs inv 4
        assert list(out[2]) == [2, 2, 2, 2]
