"""Observability layer (repro.obs + the serving-stack instrumentation).

Covers the contracts ``docs/observability.md`` promises:

* the Tracer's bounded rings trim in bulk and count what they dropped;
* ``REPRO_TRACE=0`` and ``EngineConfig.trace`` kill recording entirely
  (no events, no ledger, no counters — the hot path stays untouched);
* the Perfetto export is a deterministic function of the ring contents
  (goldened on a hand-built tracer with fixed timestamps);
* a real 2-replica multi-adapter fleet run produces a structurally
  valid trace: phase spans per step, placement events per submission,
  lifecycle summaries per finished request, and a Perfetto JSON whose
  request timelines expand to queue/prefill/decode spans;
* the cache-reuse ledger reconciles EXACTLY with the prefix cache's
  hit counters on attention-only archs (the paper's central quantity
  is accounted, not sampled).
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.alora import AdapterSpec, init_adapter_weights
from repro.models import init_params
from repro.obs import (TRACE_RING_KEEP, TRACE_RING_MAX, Tracer,
                       d2h_summary, prometheus_text, reuse_by_adapter,
                       to_perfetto, trace_records)
from repro.obs.tracer import trace_enabled_default
from repro.serving import Engine, EngineConfig
from repro.serving.router import Router

KEY = jax.random.key(0)
INV = (7, 8, 9)
ARCH = "granite-3.2-8b"


@pytest.fixture(scope="module")
def zoo():
    """(cfg, params, adapters) for the attention-only arch, built once."""
    cfg = get_reduced(ARCH)
    params = init_params(KEY, cfg)
    ads = [(AdapterSpec(f"ad{i}", rank=8,
                        invocation_tokens=INV if i % 2 else None),
            init_adapter_weights(jax.random.key(100 + i), cfg, 8))
           for i in range(2)]
    return cfg, params, ads


def mk_engine(zoo, **ecfg_kw):
    cfg, params, ads = zoo
    kw = dict(max_running=4, max_batched_tokens=64, adapter_slots=2)
    kw.update(ecfg_kw)
    return Engine(cfg, params, adapters=ads,
                  engine_cfg=EngineConfig(**kw))


def run_multiturn(target, cfg, *, sessions=3, turns=2, gen=4, seed=3):
    """Sequential multi-turn trace: each round runs to idle before the
    next extends its prompts, so later turns' admission probes actually
    find the earlier turns' blocks registered (nonzero reuse)."""
    rng = np.random.RandomState(seed)
    hi = min(400, cfg.vocab_size)
    convo = [list(rng.randint(10, hi, 24 + 4 * (s % 3)))
             for s in range(sessions)]
    ids = []
    for t in range(turns):
        round_ids = []
        for s in range(sessions):
            adapter = f"ad{s % 2}" if t % 2 else None
            round_ids.append(target.submit(convo[s], gen,
                                           adapter_name=adapter))
        target.run_until_idle()
        for s, rid in enumerate(round_ids):
            out = target.request(rid).output_tokens
            assert len(out) == gen
            convo[s] = convo[s] + list(out) + list(rng.randint(10, hi, 12))
        ids.extend(round_ids)
    return ids


# ---------------------------------------------------------------------------
# ring bounds + kill switch (no engine needed)
# ---------------------------------------------------------------------------
def test_ring_overflow_trims_in_bulk_and_counts_dropped():
    tr = Tracer(enabled=True)
    extra = 10
    for i in range(TRACE_RING_MAX + extra):
        tr.span("schedule", "s", 0.0, 1.0, None)
    # at the threshold the OLDEST half goes in one bulk del, then
    # appends resume — never a per-append pop
    assert len(tr.events) == TRACE_RING_KEEP + extra
    assert tr.dropped == TRACE_RING_MAX - TRACE_RING_KEEP
    # the dropped count is surfaced by the flat exporter
    recs = trace_records([tr])
    assert {"kind": "dropped", "value": tr.dropped,
            "replica": 0} in recs


def test_env_kill_switch(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "0")
    assert not trace_enabled_default()
    tr = Tracer()                        # inherits the env default
    tr.span("schedule", "s", 0.0, 1.0, None)
    tr.event("pool", "prefetch", None)
    tr.count("x")
    tr.ledger_entry(0, None, 8, 8, False, 0.0)
    tr.request_summary(0, None, 0.0, 1.0, 2.0, 3.0, 16, 4, 0)
    assert not tr.events and not tr.ledger and not tr.counters
    # an explicit enabled=True overrides the environment (the A/B the
    # overhead benchmark runs)
    assert Tracer(enabled=True).enabled


def test_engine_trace_off_is_silent(zoo):
    """EngineConfig.trace=False: the whole stack (engine, runner, pool)
    records nothing — rings stay empty, counters stay empty."""
    cfg, _, _ = zoo
    eng = mk_engine(zoo, trace=False)
    run_multiturn(eng, cfg, sessions=2, turns=1)
    assert not eng.tracer.events
    assert not eng.tracer.ledger
    assert not eng.tracer.counters
    assert not eng.adapter_pool.tracer.enabled


# ---------------------------------------------------------------------------
# Perfetto export golden (hand-built rings, fixed timestamps)
# ---------------------------------------------------------------------------
def test_perfetto_export_golden():
    """to_perfetto is a pure function of the ring contents: a hand-built
    tracer with fixed timestamps produces exactly this JSON.  (Only
    ``Tracer.event`` stamps its own wall clock, so the golden uses
    spans, a ledger row and a request summary — all caller-timed.)"""
    tr = Tracer(enabled=True, replica=0)
    tr.span("schedule", "schedule", 1.0, 1.5, 5.0, {"n": 2})
    tr.ledger_entry(0, "ad0#v1", 32, 16, False, 5.0)
    tr.request_summary(0, "ad0#v1", arrival=0.0, t_prefill_start=1.0,
                       t_decode_start=2.0, t_done=3.0, prompt_len=48,
                       output_len=8, cache_hit_tokens=32)
    got = to_perfetto([tr])
    life_args = {"req_id": 0, "adapter_uid": "ad0#v1", "arrival": 0.0,
                 "t_prefill_start": 1.0, "t_decode_start": 2.0,
                 "t_done": 3.0, "prompt_len": 48, "output_len": 8,
                 "cache_hit_tokens": 32}
    want = {"traceEvents": [
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": "replica 0 · step phases"}},
        {"name": "thread_name", "ph": "M", "pid": 1, "tid": 1,
         "args": {"name": "schedule"}},
        {"name": "schedule", "pid": 1, "tid": 1, "ts": 1.0e6,
         "args": {"n": 2, "vclock": 5.0}, "ph": "X", "dur": 0.5e6},
        {"name": "process_name", "ph": "M", "pid": 1001, "tid": 0,
         "args": {"name": "replica 0 · requests (virtual clock)"}},
        {"name": "thread_name", "ph": "M", "pid": 1001, "tid": 1,
         "args": {"name": "req 0 [ad0#v1]"}},
        {"name": "queue", "ph": "X", "pid": 1001, "tid": 1, "ts": 0.0,
         "dur": 1.0e6, "args": life_args},
        {"name": "prefill", "ph": "X", "pid": 1001, "tid": 1,
         "ts": 1.0e6, "dur": 1.0e6, "args": life_args},
        {"name": "decode", "ph": "X", "pid": 1001, "tid": 1, "ts": 2.0e6,
         "dur": 1.0e6, "args": life_args},
        {"name": "admit", "ph": "i", "s": "t", "pid": 1001, "tid": 1,
         "ts": 5.0e6,
         "args": {"adapter_uid": "ad0#v1", "reused": 32,
                  "recomputed": 16, "state_reused": False}},
    ], "displayTimeUnit": "ms"}
    assert got == want
    json.dumps(got)                      # serializable as-is


def test_prometheus_text_format():
    a = Tracer(enabled=True, replica=0)
    b = Tracer(enabled=True, replica=-1)
    a.count("steps_total", 3)
    b.count("placements_total", 2)
    text = prometheus_text([a, b])
    assert text == ("# TYPE repro_placements_total counter\n"
                    'repro_placements_total{replica="router"} 2\n'
                    "# TYPE repro_steps_total counter\n"
                    'repro_steps_total{replica="0"} 3\n')


def test_d2h_summary_aggregates_per_tag():
    out = d2h_summary([(3, "int32", "step"), (2, "int32", "step"),
                       (128, "float32", "admit")])
    assert out["step"] == {"count": 2.0, "elems": 5.0, "bytes": 20.0}
    assert out["admit"]["bytes"] == 128 * 4


# ---------------------------------------------------------------------------
# ledger ↔ prefix-cache reconciliation (the paper's central quantity)
# ---------------------------------------------------------------------------
def test_ledger_reconciles_with_prefix_cache_hits(zoo):
    """Over a run without admission failures on an attention-only arch,
    Σ ledger.reused == BlockManager.hits × block_size EXACTLY — the
    per-request ledger is an accounting of the same block-level probes
    the cache counts, not an estimate."""
    cfg, _, _ = zoo
    eng = mk_engine(zoo, max_running=8, max_batched_tokens=128)
    ids = run_multiturn(eng, cfg, sessions=3, turns=2)
    led = eng.tracer.ledger
    assert len(led) == len(ids)          # one row per admission
    reused = sum(r[2] for r in led)
    recomputed = sum(r[3] for r in led)
    bs = eng.ecfg.block_size
    assert reused == eng.kv_mgr.hits * bs
    assert reused > 0                    # turn 2 actually hit turn 1
    # counters mirror the ledger totals
    assert eng.tracer.counters["tokens_reused_total"] == reused
    assert eng.tracer.counters["tokens_recomputed_total"] == recomputed
    assert eng.tracer.counters["admissions_total"] == len(ids)
    # per-adapter roll-up is consistent and the aLoRA rows reuse
    # base-model blocks (cross-model reuse, the paper's mechanism)
    table = reuse_by_adapter([eng.tracer])
    assert sum(r["reused"] for r in table.values()) == reused
    assert any(uid != "base" and r["reused"] > 0
               for uid, r in table.items())


# ---------------------------------------------------------------------------
# fleet run: structural trace golden over 2 replicas
# ---------------------------------------------------------------------------
def test_fleet_trace_structure(zoo):
    cfg, params, ads = zoo
    kw = dict(max_running=4, max_batched_tokens=64, adapter_slots=2)
    router = Router([Engine(cfg, params, adapters=ads,
                            engine_cfg=EngineConfig(**kw))
                     for _ in range(2)])
    ids = run_multiturn(router, cfg, sessions=4, turns=2)

    # the router stamped fleet positions and logged every placement
    assert [e.tracer.replica for e in router.replicas] == [0, 1]
    assert router.tracer.replica == -1
    placements = [e for e in router.tracer.events if e[2] == "placement"]
    assert len(placements) == len(ids)
    assert router.tracer.counters["placements_total"] == len(ids)

    tracers = [e.tracer for e in router.replicas] + [router.tracer]
    for eng in router.replicas:
        tr = eng.tracer
        names = {(e[0], e[1], e[2]) for e in tr.events}
        # every work step leaves one span per phase
        for phase in ("schedule", "submit", "retire"):
            assert ("span", phase, phase) in names, phase
        spans = [e for e in tr.events
                 if e[0] == "span" and e[1] == "schedule"]
        assert len(spans) == tr.counters["steps_total"]
        # lifecycle: one arrival event + one finish summary per request
        arrivals = [e for e in tr.events if e[2] == "arrival"]
        summaries = [e for e in tr.events if e[0] == "request"]
        assert len(arrivals) == len(summaries)
        assert tr.counters["requests_finished_total"] == len(summaries)
        # schema: every record is a 7-tuple on a known track
        for e in tr.events:
            assert len(e) == 7
            assert e[1] in ("schedule", "submit", "retire", "pool",
                            "router", "lifecycle")
    # both replicas actually served work (affinity spread the sessions)
    assert all(e.tracer.counters.get("steps_total", 0) > 0
               for e in router.replicas)

    # Perfetto export: loads, and every finished request expands into
    # queue/prefill/decode spans on its replica's request process
    doc = json.loads(json.dumps(to_perfetto(tracers)))
    pids = {e["pid"] for e in doc["traceEvents"]}
    assert {1, 2, 1001, 1002, 2001} <= pids
    life = [e for e in doc["traceEvents"]
            if e.get("ph") == "X" and e["pid"] in (1001, 1002)]
    by_name = {}
    for e in life:
        by_name.setdefault(e["name"], []).append(e)
        assert e["dur"] >= 0.0
    n_fin = sum(e.tracer.counters["requests_finished_total"]
                for e in router.replicas)
    assert len(by_name["prefill"]) == len(by_name["decode"]) == n_fin
    # phase spans land on the wall-clock phase processes
    phase = [e for e in doc["traceEvents"]
             if e.get("ph") == "X" and e["pid"] in (1, 2)]
    assert {e["name"] for e in phase} >= {"schedule", "submit", "retire"}

    # flat records cover every ring; prometheus text parses per family
    recs = trace_records(tracers)
    assert sum(1 for r in recs if r.get("kind") == "ledger") == \
        sum(len(e.tracer.ledger) for e in router.replicas)
    text = prometheus_text(tracers)
    for line in text.splitlines():
        assert line.startswith("# TYPE repro_") or \
            line.startswith("repro_"), line

    # fleet-level reconciliation: summed ledger reuse == summed
    # prefix-cache hits × block_size across the fleet
    reused = sum(r[2] for t in tracers for r in t.ledger)
    bs = router.replicas[0].ecfg.block_size
    assert reused == sum(e.kv_mgr.hits for e in router.replicas) * bs
    assert reused > 0


def test_async_engine_trace_has_overlapping_phases(zoo):
    """Async submission: the submit span of step N and the retire span
    of step N's previous in-flight work both exist; d2h retire events
    carry the int32 step tag (the ids-only invariant, visible in the
    trace)."""
    cfg, _, _ = zoo
    eng = mk_engine(zoo, max_running=8, max_batched_tokens=128)
    run_multiturn(eng, cfg, sessions=3, turns=1)
    d2h = [e for e in eng.tracer.events if e[2] == "d2h"]
    step_fetches = [e for e in d2h if (e[6] or {}).get("tag") == "step"]
    assert step_fetches
    assert all(e[6]["dtype"] == "int32" for e in step_fetches)
    assert eng.tracer.counters["d2h_step_transfers_total"] == \
        len(step_fetches)
