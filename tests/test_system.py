"""End-to-end system behaviour: the paper's headline claims at reduced
scale — aLoRA beats vanilla LoRA on the adapter-evaluation step via
cross-model prefix-cache reuse, with hit rates matching §4.2."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.alora import AdapterSpec, init_adapter_weights
from repro.models import init_params
from repro.serving import Engine, EngineConfig, speedup_table
from repro.serving import pipelines as P

KEY = jax.random.key(0)
INV = (7, 8, 9)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("granite-3.2-8b")
    params = init_params(KEY, cfg)
    w = init_adapter_weights(jax.random.key(7), cfg, 8)
    return cfg, params, w


def run_pipeline(cfg, params, w, kind, seed, **ecfg_kw):
    spec = AdapterSpec("uq", rank=8,
                       invocation_tokens=INV if kind == "alora" else None)
    eng = Engine(cfg, params, adapters=[(spec, w)],
                 engine_cfg=EngineConfig(**ecfg_kw))
    res = P.base_adapter(eng, adapter_names=["uq"], prompt_len=96,
                         gen_len=32, eval_len=8, batch=2,
                         feed_back_to_base=True, seed=seed)
    return eng, res


def test_paper_headline_speedup(setup):
    """aLoRA's evaluation step must beat LoRA's on prefill and TTFT once
    jit caches are warm (the paper's Fig. 6 effect, reduced scale).

    Runs the SYNCHRONOUS oracle (async_submission=False): stage-time
    ratios are defined under the fully-charged virtual clock, where a
    step's entire device time lands in its stage.  The async pipeline
    deliberately hides device time under host work, which compresses
    per-stage attribution (both variants' prefill waits shrink toward
    the non-overlapped remainder) while leaving tokens and e2e intact —
    its own equivalence suite lives in test_sharded_step.py."""
    cfg, params, w = setup
    # warmup: compile every bucket for both variants
    for kind in ("lora", "alora"):
        run_pipeline(cfg, params, w, kind, seed=99,
                     async_submission=False)
    rows = {k: run_pipeline(cfg, params, w, k, seed=0,
                            async_submission=False)
            for k in ("lora", "alora")}
    m_lora = rows["lora"][1].stage_metrics(rows["lora"][0], "eval")
    m_alora = rows["alora"][1].stage_metrics(rows["alora"][0], "eval")
    sp = speedup_table(m_lora, m_alora)
    assert sp["prefill"] > 1.5, sp
    assert sp["ttft"] > 1.2, sp
    # cache hit rates: aLoRA high, LoRA zero (paper §4.2: 84% @ 1k)
    assert m_alora.means["cache_hit_frac"] > 0.7
    assert m_lora.means["cache_hit_frac"] == 0.0


def test_outputs_identical_across_variants(setup):
    """LoRA vs aLoRA change WHERE adapters apply, not the base pipeline:
    the base-model generations must be identical in both runs."""
    cfg, params, w = setup
    outs = {}
    for kind in ("lora", "alora"):
        eng, res = run_pipeline(cfg, params, w, kind, seed=1)
        outs[kind] = [eng.request(r).output_tokens for r in res.base_ids]
    assert outs["lora"] == outs["alora"]
