"""§Perf optimization variants must preserve numerics.

Each Runtime knob exercised by the hillclimbing iterations is checked
against the baseline path on a 1-device mesh (semantics) — the roofline
effects are measured by the dry-run (EXPERIMENTS.md §Perf)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.launch.mesh import make_host_mesh
from repro.models import (decode_step, forward_full, init_decode_caches,
                          init_params, logits_for)
from repro.models.attention import (dequantize_kv, flash_attention,
                                    flash_attention_remat, quantize_kv)
from repro.models.model import Runtime

KEY = jax.random.key(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("stablelm-12b")
    return cfg, init_params(KEY, cfg)


def test_context_parallel_forward_exact(setup):
    cfg, params = setup
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0,
                              cfg.vocab_size)
    h0, _, _ = forward_full(params, cfg, toks)
    rt = Runtime(mesh=make_host_mesh(), batch_axes=("data",),
                 shard_activations=True, context_parallel=True)
    h1, _, _ = forward_full(params, cfg, toks, rt)
    np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))


def test_context_parallel_grads_close(setup):
    cfg, params = setup
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0,
                              cfg.vocab_size)
    rt = Runtime(mesh=make_host_mesh(), batch_axes=("data",),
                 shard_activations=True, context_parallel=True)

    def loss(params, rt):
        h, _, _ = forward_full(params, cfg, toks, rt)
        return jnp.sum(h.astype(jnp.float32) ** 2)

    g0 = jax.grad(loss)(params, Runtime())
    g1 = jax.grad(loss)(params, rt)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-4)


def test_flash_remat_gradients_match_baseline():
    ks = jax.random.split(KEY, 3)
    S, H, KV, hd = 48, 4, 2, 16
    q = jax.random.normal(ks[0], (2, S, H, hd))
    k = jax.random.normal(ks[1], (2, S, KV, hd))
    v = jax.random.normal(ks[2], (2, S, KV, hd))

    def l0(q, k, v):
        return (flash_attention(q, k, v, q_block=16, kv_block=16)
                ** 2).sum()

    def l1(q, k, v):
        return (flash_attention_remat(q, k, v, True, 0, 0, 16, 16)
                ** 2).sum()

    g0 = jax.grad(l0, argnums=(0, 1, 2))(q, k, v)
    g1 = jax.grad(l1, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g0, g1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_kv_quant_roundtrip_error_bounded():
    x = jax.random.normal(KEY, (4, 8, 2, 32))
    q, s = quantize_kv(x)
    x2 = dequantize_kv(q, s, x.dtype)
    rel = float(jnp.abs(x2 - x).max() / jnp.abs(x).max())
    assert rel < 0.01
    assert q.dtype == jnp.int8


def test_kv_quant_decode_argmax_preserved(setup):
    cfg, params = setup
    B, S = 2, 20
    toks = jax.random.randint(jax.random.key(2), (B, S), 0,
                              cfg.vocab_size)
    h, _, _ = forward_full(params, cfg, toks)
    want = logits_for(params, cfg, h)[:, -1]
    rt_q = Runtime(kv_cache_quant=True)
    caches = init_decode_caches(cfg, B, 32, rt_q)
    lg = None
    for t in range(S):
        lg, caches = decode_step(params, cfg, toks[:, t:t + 1], caches,
                                 t, rt_q)
    rel = float(jnp.abs(lg[:, 0] - want).max() / jnp.abs(want).max())
    assert rel < 0.02
    assert bool((jnp.argmax(lg[:, 0], -1) == jnp.argmax(want, -1)).all())
