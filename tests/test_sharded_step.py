"""Sharded ≡ unsharded and async ≡ sync equivalence of the ONE jitted
mixed ragged step.

The TP-sharded serving path (``EngineConfig.mesh``) must be a pure
layout change, and the async step pipeline
(``EngineConfig.async_submission``, schedule → submit → retire with
one-step-lookahead submission) must be a pure SCHEDULING-OVERLAP
change: running the same workload on a ``(data=2, model=4)`` host mesh
and/or with async submission has to produce token-for-token identical
outputs to the synchronous single-device oracle — across architecture
families (attention, SSM, encoder-decoder), with dynamic adapter churn,
recompute-preemption and prefix-cache reuse in the loop — while keeping
the mixed path's 1.0-device-calls-per-step and
zero-post-warmup-recompile invariants.

Mesh-bearing tests need 8 host devices (``needs_mesh``); the CI
``sharded`` and ``async`` legs run them with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` exported before
jax initializes, and they skip under the plain 1-device tier-1
invocation.  The single-device async ≡ sync oracle tests run
everywhere.
"""
import jax
import numpy as np
import pytest

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 host devices — run with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI "
           "'sharded'/'async' legs)")

from repro.configs import get_reduced
from repro.core.alora import AdapterSpec, init_adapter_weights
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.serving import Engine, EngineConfig
from repro.serving import runner as runner_mod

KEY = jax.random.key(0)
INV = (7, 8, 9)
ARCHS = ["granite-3.2-8b", "mamba2-2.7b", "whisper-large-v3"]


def scaled_adapter(cfg, seed, rank=8, scale=30.0):
    """Adapter with amplified B so adapted tokens actually diverge from
    the base model's (random-init B is too small to flip argmaxes)."""
    w = init_adapter_weights(jax.random.key(seed), cfg, rank)
    return {seg: {k: (v * scale if k.startswith("b") else v)
                  for k, v in leaves.items()}
            for seg, leaves in w.items()}


@pytest.fixture(scope="module")
def zoo():
    """Lazily-built (cfg, params, adapters) per arch, shared across the
    module so each family compiles once."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_reduced(arch)
            params = init_params(KEY, cfg)
            ads = [(AdapterSpec(f"ad{i}", rank=8,
                                invocation_tokens=INV if i % 2 else None),
                    scaled_adapter(cfg, 100 + i))
                   for i in range(3)]
            cache[arch] = (cfg, params, ads)
        return cache[arch]

    return get


def mk_engine(zoo, arch, mesh, **ecfg_kw):
    cfg, params, ads = zoo(arch)
    kw = dict(max_running=4, max_batched_tokens=64, adapter_slots=2,
              mesh=mesh)
    kw.update(ecfg_kw)
    return Engine(cfg, params, adapters=ads, engine_cfg=EngineConfig(**kw))


def run_workload(eng, *, n=5, gen=6, prompt_len=40, seed=5):
    """Deterministic mixed workload: staggered arrivals (prefill/decode
    overlap), an adapter mix cycling through MORE adapters than device
    slots (churn), and one identical-prompt pair (prefix-cache reuse).
    Returns (tokens per request, stats)."""
    cfg = eng.cfg
    rng = np.random.RandomState(seed)
    shared = list(rng.randint(10, 500, prompt_len))
    rids = []
    for i in range(n):
        prompt = shared if i < 2 else \
            list(rng.randint(10, 500, prompt_len + 8 * (i % 3)))
        kw = {}
        if cfg.is_encoder_decoder:
            kw = dict(frame_embeds=np.random.RandomState(77).randn(
                cfg.encoder_seq_len, cfg.d_model).astype(np.float32))
        names = [None, "ad0", "ad1", "ad2"]
        rids.append(eng.submit(list(prompt), gen,
                               adapter_name=names[i % len(names)],
                               arrival_time=1e-9 * i, **kw))
    steps = 0
    calls0 = eng.runner.call_counts["mixed_step"]
    while eng.pending or eng.waiting or eng.running:
        eng.step()
        if any(eng.last_step_tokens):
            steps += 1
    # second wave: an aLoRA request re-sends the shared prompt AFTER the
    # base request's blocks are registered — the paper's cross-model
    # prefix reuse (base-aligned hashes) must hit under sharding too
    kw = {}
    if cfg.is_encoder_decoder:
        kw = dict(frame_embeds=np.random.RandomState(77).randn(
            cfg.encoder_seq_len, cfg.d_model).astype(np.float32))
    rids.append(eng.submit(list(shared), gen, adapter_name="ad1", **kw))
    while eng.pending or eng.waiting or eng.running:
        eng.step()
        if any(eng.last_step_tokens):
            steps += 1
    stats = dict(
        steps=steps,
        mixed_calls=eng.runner.call_counts["mixed_step"] - calls0,
        preemptions=eng.preemptions,
        hits=[eng.request(r).n_cache_hit_tokens for r in rids],
        evictions=eng.adapter_pool_stats().evictions,
    )
    return [eng.request(r).output_tokens for r in rids], stats


# ---------------------------------------------------------------------------
# token-for-token equivalence per architecture family
# ---------------------------------------------------------------------------
@needs_mesh
@pytest.mark.parametrize("arch", ARCHS)
def test_sharded_matches_single_device(zoo, arch):
    """(data=2, model=4) mixed step ≡ single-device mixed step, token for
    token, under adapter churn + prefix reuse; exactly one jitted mixed
    call per work step on the sharded side."""
    base_toks, base_st = run_workload(mk_engine(zoo, arch, None))
    mesh = make_host_mesh(data=2, model=4)
    sh_toks, sh_st = run_workload(mk_engine(zoo, arch, mesh))
    assert sh_toks == base_toks
    assert all(t for t in sh_toks)
    # scheduling is device-layout independent: identical cache hits,
    # churn and step counts on both sides
    assert sh_st["hits"] == base_st["hits"]
    assert sh_st["steps"] == base_st["steps"]
    # the second-wave aLoRA request actually reused the base request's
    # registered prefix blocks (cross-model reuse under sharding) …
    assert sh_st["hits"][-1] > 0
    # … and 3 adapters cycled through 2 slots (real churn)
    assert sh_st["evictions"] > 0
    # the unified-step invariant survives sharding
    assert sh_st["mixed_calls"] == sh_st["steps"]


@needs_mesh
def test_preemption_recompute_equivalence(zoo):
    """Block starvation → recompute-preemption fires on BOTH sides at the
    same step and the re-prefill (through the prefix cache) reproduces
    identical tokens under sharding.  Equal-length prompts with a pool
    sized to exactly the running prompts make every running request hit
    its next block boundary in the SAME step with zero free blocks — the
    zero-progress condition the preemption path requires."""

    def run(mesh):
        eng = mk_engine(zoo, "granite-3.2-8b", mesh, num_blocks=8,
                        max_running=2)
        rng = np.random.RandomState(11)
        prompts = [list(rng.randint(10, 500, 64)) for _ in range(3)]
        rids = [eng.submit(p, 8, adapter_name="ad1" if i == 1 else None)
                for i, p in enumerate(prompts)]
        eng.run_until_idle()
        return ([eng.request(r).output_tokens for r in rids],
                eng.preemptions)

    base_toks, base_pre = run(None)
    sh_toks, sh_pre = run(make_host_mesh(data=2, model=4))
    assert base_pre > 0, "workload never preempted"
    assert sh_pre == base_pre
    assert sh_toks == base_toks


# ---------------------------------------------------------------------------
# compile-cache discipline under sharding
# ---------------------------------------------------------------------------
@needs_mesh
def test_zero_postwarmup_recompiles_sharded(zoo):
    """A fresh sharded engine over the same config re-uses every trace of
    a previous one (module-level jit + value-equal mesh/shardings): zero
    new compiles, 1.0 device-calls/step."""
    mesh = make_host_mesh(data=2, model=4)
    run_workload(mk_engine(zoo, "granite-3.2-8b", mesh))      # warmup
    before = runner_mod.jit_cache_size()
    toks, st = run_workload(
        mk_engine(zoo, "granite-3.2-8b", make_host_mesh(data=2, model=4)))
    assert runner_mod.jit_cache_size() - before == 0, \
        "post-warmup recompiles"
    assert st["mixed_calls"] == st["steps"]


# ---------------------------------------------------------------------------
# data-parallel token sharding (EngineConfig.data_shard_tokens)
# ---------------------------------------------------------------------------
@needs_mesh
@pytest.mark.parametrize("arch", ARCHS)
def test_data_shard_off_matches_on(zoo, arch):
    """Token sharding is a pure layout change: the same mesh with
    ``data_shard_tokens=False`` (replicate-everything TP, the pre-change
    layout) ≡ the data-sharded default, token for token, with identical
    scheduling (cache hits, step counts) and the one-call-per-step
    invariant on both sides."""
    mesh = make_host_mesh(data=2, model=4)
    on_toks, on_st = run_workload(mk_engine(zoo, arch, mesh))
    off_toks, off_st = run_workload(
        mk_engine(zoo, arch, make_host_mesh(data=2, model=4),
                  data_shard_tokens=False))
    assert on_toks == off_toks
    assert all(t for t in on_toks)
    assert on_st["hits"] == off_st["hits"]
    assert on_st["steps"] == off_st["steps"]
    assert on_st["mixed_calls"] == on_st["steps"]
    assert off_st["mixed_calls"] == off_st["steps"]


@needs_mesh
def test_data_shard_token_layouts(zoo):
    """The runner actually splits the packed token axis: per-token meta
    and embed leaves carry P("data") layouts, the token bucket floor
    equals the data-axis size (so every pow2 bucket divides), and the
    per-request/sampled leaves stay replicated.  With the knob off — or
    with a size-1 data axis — everything degrades to the replicated
    TP-only layout."""
    from jax.sharding import PartitionSpec as P

    eng = mk_engine(zoo, "granite-3.2-8b", make_host_mesh(data=2, model=4))
    r = eng.runner
    assert r._tok_bucket_lo == 2
    assert r._shard.tok_meta == P("data")
    assert r._shard.tok_embeds == P("data", None)
    # meta tuple layout: leaf 0 (tok_ids) token-sharded, leaf 1 (embeds)
    # token-sharded on dim 0, leaf 14 (run_slots) replicated
    assert r._meta_sharding[0].spec == P("data")
    assert r._meta_sharding[1].spec == P("data", None)
    assert r._meta_sharding[14].spec == P()

    off = mk_engine(zoo, "granite-3.2-8b", make_host_mesh(data=2, model=4),
                    data_shard_tokens=False)
    assert off.runner._tok_bucket_lo == 1
    assert off.runner._shard.tok_meta == P(None)

    model_only = mk_engine(zoo, "granite-3.2-8b",
                           make_host_mesh(data=1, model=8))
    assert model_only.runner._tok_bucket_lo == 1
    assert model_only.runner._shard.tok_meta == P(None)


def test_token_bucket_floor_divisibility():
    """pow2 buckets double FROM the floor, so every bucket the assembly
    can produce is a multiple of the data-axis size."""
    for lo in (1, 2, 4):
        for n in range(1, 70):
            b = runner_mod.next_pow2(n, lo=lo)
            assert b >= n and b % lo == 0, (n, lo, b)


# ---------------------------------------------------------------------------
# knob validation / default-path isolation
# ---------------------------------------------------------------------------
@needs_mesh
def test_sequential_mode_rejected_under_mesh(zoo):
    with pytest.raises(ValueError, match="mixed"):
        mk_engine(zoo, "granite-3.2-8b", make_host_mesh(data=2, model=4),
                  execution_mode="sequential")


@needs_mesh
def test_pallas_impls_rejected_under_mesh(zoo):
    with pytest.raises(ValueError, match="Pallas"):
        mk_engine(zoo, "granite-3.2-8b", make_host_mesh(data=2, model=4),
                  mixed_attn_impl="pallas_interpret")


@needs_mesh
def test_default_engine_stays_single_device(zoo):
    """mesh=None on a multi-device host keeps everything on one device —
    the pre-sharding behavior, byte for byte."""
    eng = mk_engine(zoo, "granite-3.2-8b", None)
    assert eng.runner.mesh is None and eng.runner._shard is None
    assert len(eng.runner.k_pool.devices()) == 1


@needs_mesh
def test_host_mesh_validates_device_count():
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_host_mesh(data=1000, model=1000)


# ---------------------------------------------------------------------------
# async ≡ sync oracle (EngineConfig.async_submission) — the one-step-
# lookahead pipeline must be token-for-token identical to the
# synchronous oracle.  Single-device legs run everywhere (tier-1); the
# async × mesh combination needs the 8-device CI legs.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCHS)
def test_async_matches_sync_oracle(zoo, arch):
    """async_submission=True (the default) ≡ async_submission=False,
    token for token on every arch family, with adapter churn and
    cross-model prefix reuse in the loop; the 1.0-device-calls/step
    invariant survives the pipeline split."""
    sync_toks, sync_st = run_workload(
        mk_engine(zoo, arch, None, async_submission=False))
    async_toks, async_st = run_workload(mk_engine(zoo, arch, None))
    assert async_toks == sync_toks
    assert all(t for t in async_toks)
    # churn + cross-model reuse really happened on the async side
    assert async_st["evictions"] > 0
    assert async_st["hits"][-1] > 0
    # one submitted jitted step per work step, even with retirement
    # running one step behind
    assert async_st["mixed_calls"] == async_st["steps"]


def test_async_preemption_recompute_equivalence(zoo):
    """Block starvation under async submission: recompute-preemption
    only ever fires with the pipeline drained (no in-flight step), the
    preempted request replays host-known tokens only (PENDING
    placeholders are dropped with the claim), and the outputs stay
    identical to the synchronous oracle."""

    def run(async_on):
        eng = mk_engine(zoo, "granite-3.2-8b", None, num_blocks=8,
                        max_running=2, async_submission=async_on)
        rng = np.random.RandomState(11)
        prompts = [list(rng.randint(10, 500, 64)) for _ in range(3)]
        rids = [eng.submit(p, 8, adapter_name="ad1" if i == 1 else None)
                for i, p in enumerate(prompts)]
        eng.run_until_idle()
        return ([eng.request(r).output_tokens for r in rids],
                eng.preemptions)

    sync_toks, sync_pre = run(False)
    async_toks, async_pre = run(True)
    assert sync_pre > 0 and async_pre > 0, "workload never preempted"
    assert async_toks == sync_toks
    assert all(len(t) == 8 for t in async_toks)


def test_async_overlaps_and_ships_ids_only(zoo):
    """Pipeline-shape invariants: every work step after the first is
    assembled while the previous step is still in flight, and the only
    per-step device→host transfer is the (R,) int32 sampled-ids array —
    never the (R, vocab) logits."""
    eng = mk_engine(zoo, "granite-3.2-8b", None)
    _, st = run_workload(eng)
    assert eng.use_async
    # two waves -> two pipeline fills; everything else overlapped
    assert eng.async_overlap_steps >= st["steps"] - 2
    steps_d2h = [(e, d) for e, d, tag in eng.runner.d2h_fetches
                 if tag == "step"]
    assert steps_d2h and all(d == "int32" for _, d in steps_d2h)
    assert max(e for e, _ in steps_d2h) < eng.cfg.vocab_size


@needs_mesh
@pytest.mark.parametrize("arch", ARCHS)
def test_async_sharded_matches_sync_oracle(zoo, arch):
    """The async pipeline composes with TP sharding: async submission
    over the (data=2, model=4) host mesh ≡ the synchronous single-device
    oracle, token for token, with churn + prefix reuse in the loop."""
    base_toks, _ = run_workload(
        mk_engine(zoo, arch, None, async_submission=False))
    sh_toks, sh_st = run_workload(
        mk_engine(zoo, arch, make_host_mesh(data=2, model=4)))
    assert sh_toks == base_toks
    assert all(t for t in sh_toks)
    assert sh_st["mixed_calls"] == sh_st["steps"]
