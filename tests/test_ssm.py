"""Mamba2 SSD: chunked-scan algebra, state carry, masking, boundaries."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_reduced
from repro.models.ssm import (init_ssm, init_ssm_state, ssd_decode_step,
                              ssd_forward)

KEY = jax.random.key(0)
CFG = get_reduced("mamba2-2.7b")
P = init_ssm(KEY, CFG, jnp.float32)


def x_of(B, S, seed=0):
    return jax.random.normal(jax.random.key(seed), (B, S, CFG.d_model))


def test_chunk_size_invariance():
    """SSD output must not depend on the chunk size."""
    import dataclasses
    x = x_of(2, 96)
    y1, s1, c1 = ssd_forward(P, CFG, x)
    cfg2 = CFG.replace(ssm=dataclasses.replace(CFG.ssm, chunk_size=16))
    y2, s2, c2 = ssd_forward(P, cfg2, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


def test_split_carry_equivalence():
    x = x_of(2, 100)
    y, s, c = ssd_forward(P, CFG, x)
    ya, sa, ca = ssd_forward(P, CFG, x[:, :40])
    yb, sb, cb = ssd_forward(P, CFG, x[:, 40:], ssm_state=sa,
                             conv_state=ca)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([ya, yb], 1)),
                               np.asarray(y), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sb), np.asarray(s),
                               rtol=1e-5, atol=1e-5)


def test_decode_step_matches_forward():
    """Sequential single-token decode == full forward on the suffix."""
    S, T = 32, 4
    x = x_of(1, S + T, seed=3)
    y_full, s_full, c_full = ssd_forward(P, CFG, x)
    y_pre, s, c = ssd_forward(P, CFG, x[:, :S])
    outs = []
    for t in range(T):
        y_t, s, c = ssd_decode_step(P, CFG, x[:, S + t:S + t + 1], s, c)
        outs.append(y_t)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(y_full[:, S:]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


def test_valid_len_freezes_state():
    x = x_of(1, 64, seed=4)
    y_ref, s_ref, c_ref = ssd_forward(P, CFG, x[:, :40])
    noise = jax.random.normal(jax.random.key(9), (1, 24, CFG.d_model))
    xp = jnp.concatenate([x[:, :40], noise], axis=1)
    y, s, c = ssd_forward(P, CFG, xp, valid_len=40)
    # chunk padding changes the summation order (Q=min(chunk,S)), so the
    # comparison is fp-tolerance, not bit-exact; the ENGINE path aligns
    # chunk_size == block_size where exactness holds (test_engine.py).
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(y[:, :40]), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_boundary_states_match_prefix_runs():
    x = x_of(1, 128, seed=5)
    _, _, _, (b_ssm, b_conv) = ssd_forward(P, CFG, x,
                                           return_boundary_states=True)
    Q = CFG.ssm.chunk_size
    for c_idx in range(128 // Q):
        _, s, cv = ssd_forward(P, CFG, x[:, :(c_idx + 1) * Q])
        np.testing.assert_allclose(np.asarray(b_ssm[c_idx]),
                                   np.asarray(s), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(b_conv[c_idx]),
                                   np.asarray(cv), rtol=1e-5, atol=1e-5)


@given(st.integers(1, 3), st.integers(5, 70), st.integers(1, 60))
@settings(max_examples=15, deadline=None)
def test_prop_split_anywhere(B, S1, S2):
    """State carry is exact for ANY split point (hypothesis)."""
    x = jax.random.normal(jax.random.key(S1 * 97 + S2), (B, S1 + S2,
                                                         CFG.d_model))
    y, s, _ = ssd_forward(P, CFG, x)
    ya, sa, ca = ssd_forward(P, CFG, x[:, :S1])
    yb, sb, _ = ssd_forward(P, CFG, x[:, S1:], ssm_state=sa,
                            conv_state=ca)
    np.testing.assert_allclose(np.asarray(sb), np.asarray(s),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([ya, yb], 1)), np.asarray(y),
        rtol=2e-4, atol=2e-4)
