"""Quickstart: cross-model KV-cache reuse with Activated LoRA in 60 lines.

Builds a reduced Granite-family model, registers one aLoRA "intrinsic"
(e.g. an uncertainty-quantification head), runs the paper's atomic
pipeline — base answers, adapter evaluates the answer — and shows the
adapter's prefill reusing the base model's cache blocks.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.alora import AdapterSpec, init_adapter_weights
from repro.models import init_params
from repro.serving import Engine

# 1. model + engine -----------------------------------------------------
cfg = get_reduced("granite-3.2-8b")
params = init_params(jax.random.key(0), cfg)

# 2. one Activated-LoRA adapter: identified by its invocation tokens ----
INV = (7, 8, 9)                      # the "<|uq|>" activation sequence
adapter = AdapterSpec("uq", rank=32, invocation_tokens=INV)
weights = init_adapter_weights(jax.random.key(1), cfg, rank=32)
engine = Engine(cfg, params, adapters=[(adapter, weights)])

# 3. turn 1 — the BASE model answers a prompt ---------------------------
prompt = list(np.random.RandomState(0).randint(10, cfg.vocab_size, 120))
rid = engine.submit(prompt, max_new_tokens=24)
engine.run_until_idle()
answer = engine.request(rid).output_tokens
print(f"base answered {len(answer)} tokens")

# 4. turn 2 — the aLoRA adapter EVALUATES (prompt + answer) -------------
#    its prefill transparently reuses the base model's KV blocks: only
#    tokens from the last un-cached block onward are recomputed.
eval_prompt = prompt + answer + list(INV)
rid2 = engine.submit(eval_prompt, max_new_tokens=8, adapter_name="uq")
engine.run_until_idle()
req = engine.request(rid2)
m = req.metrics()
print(f"adapter evaluation: {req.output_tokens}")
print(f"  cache reuse: {req.n_cache_hit_tokens}/{len(eval_prompt)} tokens "
      f"({m['cache_hit_frac']:.0%})  — vanilla LoRA would reuse 0")
print(f"  TTFT {m['ttft']*1e3:.1f} ms   prefill {m['prefill']*1e3:.1f} ms "
      f"  E2E {m['e2e']*1e3:.1f} ms")
assert req.n_cache_hit_tokens > 0
