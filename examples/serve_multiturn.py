"""End-to-end serving driver: batched multi-turn, multi-adapter traffic
through the full engine (continuous batching + chunked prefill + paged
KV cache + cross-model reuse), LoRA baseline vs aLoRA.

This is the paper's base→adapter→base pipeline (Fig. 4) over a batch of
concurrent conversations, reporting per-stage latencies per Table 2.

  PYTHONPATH=src python examples/serve_multiturn.py [--arch granite-3.2-8b]
"""
import argparse

import jax

from repro.configs import ASSIGNED_ARCHS, get_reduced
from repro.core.alora import AdapterSpec, init_adapter_weights
from repro.models import init_params
from repro.serving import Engine, fmt_speedups, speedup_table
from repro.serving import pipelines as P


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3.2-8b",
                    choices=ASSIGNED_ARCHS + ["granite-3.2-8b"])
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=96)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    print(f"== serving {cfg.name} ({cfg.arch_type}), "
          f"{args.batch} concurrent conversations ==")
    params = init_params(jax.random.key(0), cfg)
    INV = (7, 8, 9)
    w8 = init_adapter_weights(jax.random.key(1), cfg, 8)
    w32 = init_adapter_weights(jax.random.key(1), cfg, 32)

    results = {}
    for kind, rank, w in (("lora", 8, w8), ("alora", 32, w32)):
        inv = INV if kind == "alora" else None
        spec = AdapterSpec("judge", rank=rank, invocation_tokens=inv)
        for seed in (99, 0):                      # warmup + measured
            eng = Engine(cfg, params, adapters=[(spec, w)])
            res = P.base_adapter(
                eng, adapter_names=["judge"], prompt_len=args.prompt_len,
                gen_len=32, eval_len=8, batch=args.batch,
                feed_back_to_base=True, seed=seed)
        results[kind] = (eng, res)
        for stage in ("base", "eval", "final"):
            m = res.stage_metrics(eng, stage)
            print(f"  {kind:5s} {stage:5s}: e2e={m.means['e2e']*1e3:7.1f}ms"
                  f"  ttft={m.means['ttft']*1e3:7.1f}ms"
                  f"  prefill={m.means['prefill']*1e3:7.1f}ms"
                  f"  decode={m.means['decode']*1e3:7.1f}ms"
                  f"  hit={m.means['cache_hit_frac']:.0%}")
    sp = speedup_table(results["lora"][1].stage_metrics(
        results["lora"][0], "eval"),
        results["alora"][1].stage_metrics(results["alora"][0], "eval"))
    print("== adapter-evaluation speedup (aLoRA over LoRA baseline) ==")
    print("   " + fmt_speedups(sp))


if __name__ == "__main__":
    main()
