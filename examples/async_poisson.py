"""Asynchronous serving under Poisson arrivals (paper §4.3).

Pipeline instances arrive at rate λ; each runs base→adapter with the
adapter request submitted the instant its base request completes.  The
engine's virtual clock + measured step times reproduce queue-buildup
dynamics: watch LoRA queue times blow up at high λ while aLoRA stays
flat (no prefill backlog).

  PYTHONPATH=src python examples/async_poisson.py --rate 8
"""
import argparse

import jax

from repro.configs import get_reduced
from repro.core.alora import AdapterSpec, init_adapter_weights
from repro.models import init_params
from repro.serving import Engine
from repro.serving import pipelines as P


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = get_reduced("granite-3.2-8b")
    params = init_params(jax.random.key(0), cfg)
    INV = (7, 8, 9)

    for kind in ("lora", "alora"):
        rank = 32 if kind == "alora" else 8
        spec = AdapterSpec("judge", rank=rank,
                           invocation_tokens=INV if kind == "alora"
                           else None)
        w = init_adapter_weights(jax.random.key(1), cfg, rank)
        for seed in (99, 0):
            eng = Engine(cfg, params, adapters=[(spec, w)])
            res = P.async_base_adapter(
                eng, adapter_name="judge", arrival_rate=args.rate,
                num_requests=args.requests, prompt_len=64, gen_len=24,
                eval_len=8, seed=seed)
        m = res.stage_metrics(eng, "eval")
        print(f"{kind:5s} λ={args.rate}: eval "
              f"queue={m.means['queue']*1e3:.1f}ms "
              f"prefill={m.means['prefill']*1e3:.1f}ms "
              f"e2e={m.means['e2e']*1e3:.1f}ms "
              f"hit={m.means['cache_hit_frac']:.0%} "
              f"(p99 e2e={m.p99['e2e']*1e3:.1f}ms)")


if __name__ == "__main__":
    main()
