"""Train a ~reduced model for a few hundred steps on the synthetic LM
pipeline — the training-side end-to-end driver.

  PYTHONPATH=src python examples/train_small.py --arch zamba2-2.7b --steps 100
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0]] + (sys.argv[1:] or
                                ["--arch", "granite-3.2-8b",
                                 "--steps", "100", "--batch", "4",
                                 "--seq", "64"])
    main()
