"""Hot-path lint (Pass B of the invariant analyzer): an AST pass over
``serving/`` + ``kernels/`` enforcing the phase discipline the async
step pipeline (PR 5) established.

The serving iteration is schedule → submit → retire.  Schedule and
submit must never block on the device — the whole point of the pipeline
is that step N's host work hides under step N-1's device compute — so
inside ``Engine.step``'s call graph:

  B1  ``np.asarray`` / ``.item()`` / ``.block_until_ready()`` /
      ``jax.device_get`` are forbidden in schedule/submit-phase
      functions.  They are allowed in retire-phase functions (the one
      sanctioned sync per iteration) and the sequential-oracle path, or
      at sites annotated ``# hotpath: sync-ok`` — and every annotated
      site's function must route the transfer through the ``log_d2h``
      logger so benchmarks can still account for it.  (``np.array`` is
      the idiom for host-side construction — it never aliases a device
      buffer, so it cannot sync.)
  B2  no literal ``jnp.*`` op dispatch outside jit in the call graph
      (each eager ``jnp`` op is a separate device dispatch on the host
      path; ``jnp.asarray`` is allowlisted — it is the H2D staging
      idiom, not an op).  Eager ``.at[].set`` pool maintenance between
      steps (state snapshot/restore) is an accepted design and outside
      this rule's scope.
  B3  no ``time.*`` calls inside jit-decorated functions anywhere in
      the scanned files (a traced ``time.time()`` is a constant baked
      into the compiled step — always a bug).
  B4  trace recording (``repro.obs.tracer``, reached from the hot graph
      through ``self.tracer.*``) must be append-only plain python:
      EVERY function in the obs recording files is checked wholesale —
      any ``jax.*``/``jnp.*`` call (``obs-jax``) or blocking construct
      (``obs-sync``) is a violation, with no ``sync-ok`` annotation
      escape.  Exporters (``repro.obs.export``) are exempt: they never
      run on the step path.
  B5  phase protocol: the value-dependent state mutations PR 5
      deferred to the retire phase (``_finish_requests``, decode
      hash-chain extension, decode-block registration, preemption —
      the RETIRE_ONLY table) must be *unreachable* from schedule/
      submit-phase code.  A resolvable hot-graph call site to one of
      them is a ``phase-retire-only`` violation unless the line is
      annotated ``# phase: retire-ok (<reason>)`` — sanctioned sites
      are the drain-guarded starvation preempt in ``Engine.step`` and
      the sync-oracle-only paths, where the value dependency is
      provably satisfied.  Annotations are audited: one not attached
      to a hot call site of a RETIRE_ONLY function is ``phase-stale``.

The call graph is intraprocedural over the scanned files: ``self.x()``
resolves within the class, ``self.<attr>.x()`` through the static
attribute table below (``runner`` → ModelRunner, ``adapter_pool`` →
AdapterPool, ...).  Functions named in the phase tables MUST exist in
the scanned sources — a stale entry is itself a lint error, so the
tables cannot silently rot.  Every function in ``kernels/`` is treated
as hot for B1 (kernels execute inside the jitted step; a host sync
there is never right).

Fixture-level behavior (each rule firing and not firing) is covered in
``tests/test_analysis.py``; the same module also lints the real tree.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

SYNC_OK_ANNOTATION = "hotpath: sync-ok"
PHASE_OK_ANNOTATION = "phase: retire-ok"
D2H_LOGGER = "log_d2h"
JNP_ALLOWED = frozenset({"asarray"})

# (class, function) sets defining the retire phase (the sanctioned sync
# point) and the sequential-oracle path (synchronous by definition).
# Traversal stops at these: their callees inherit the exemption.
RETIRE_PHASE: Set[Tuple[str, str]] = {
    ("Engine", "_retire"),
    ("Engine", "_register_decode_block"),
    ("Engine", "_finish_requests"),
    ("ModelRunner", "fetch_sampled"),
}
SEQUENTIAL_ORACLE: Set[Tuple[str, str]] = {
    ("Engine", "_execute_decodes"),
    ("Engine", "_execute_prefills"),
    ("Engine", "_postprocess_decode"),
    ("Engine", "_postprocess_prefill"),
    ("ModelRunner", "execute_batch"),
    ("ModelRunner", "decode_batch"),
    ("ModelRunner", "prefill_chunk"),
}
# B5: value-dependent retire-phase mutations that schedule/submit code
# must never reach — patching PENDING placeholders, extending the
# decode hash chain, registering decode blocks and preempting all
# require token VALUES the async pipeline has not synced yet.  Hot
# call sites to these need an explicit ``# phase: retire-ok`` waiver.
RETIRE_ONLY: Set[Tuple[str, str]] = {
    ("Engine", "_finish_requests"),
    ("Engine", "_extend_hash_chain"),
    ("Engine", "_register_decode_block"),
    ("Engine", "_preempt"),
}
# instance-attribute → class resolution for cross-object calls
ATTR_CLASSES: Dict[str, str] = {
    "runner": "ModelRunner",
    "adapter_pool": "AdapterPool",
    "host_bufs": "HostBufferPool",
    "cache": "PrefixCache",
    "tracer": "Tracer",
}
# obs files exempt from the wholesale B4 recording rule: exporters run
# strictly off the step path (after a run / from a CLI), so they may do
# real work — everything else under obs/ is recording surface
OBS_EXPORT_FILES = frozenset({"export.py"})
# Router.submit is the multi-replica ADMIT path: every placement probes
# N replicas (prefix-cache walk + residency snapshot + load read), so a
# hidden device sync there would multiply by the fleet size per request.
# Router.step fans one fleet step out over every live replica — it rides
# the same no-sync budget as Engine.step, whose graph it contains.  The
# router reaches the replica-surface probes through local variables the
# intraprocedural resolver cannot follow, so those probes are rooted
# explicitly alongside Engine.submit (which Router.submit delegates to).
ROOTS: Tuple[Tuple[str, str], ...] = (
    ("Engine", "step"),
    ("Router", "submit"),
    ("Router", "step"),
    ("Engine", "submit"),
    ("Engine", "cached_prefix_tokens"),
    ("Engine", "outstanding_tokens"),
    ("Engine", "adapter_residency"),
    ("Engine", "adapter_affinity"),
    # PR 9 admission paths, rooted explicitly so B1/B2 coverage
    # survives refactors that break the intraprocedural resolution
    # (e.g. a local ``pool = self.adapter_pool`` receiver)
    ("Engine", "_admit_affinity"),
    ("AdapterPool", "tick"),
    ("AdapterPool", "can_take_slot"),
    ("AdapterPool", "affinity_of"),
)


@dataclass(frozen=True)
class Violation:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class _Func:
    path: str
    node: ast.FunctionDef
    source_lines: List[str]


def _qualname(cls: Optional[str], name: str) -> str:
    return f"{cls}.{name}" if cls else name


def _index_functions(paths: List[str]) -> Dict[Tuple[Optional[str], str],
                                               _Func]:
    """Map (class-or-None, function-name) → definition for every file."""
    funcs: Dict[Tuple[Optional[str], str], _Func] = {}
    for path in paths:
        with open(path) as f:
            src = f.read()
        lines = src.splitlines()
        tree = ast.parse(src, filename=path)
        for node in tree.body:
            if isinstance(node, ast.FunctionDef):
                funcs[(None, node.name)] = _Func(path, node, lines)
            elif isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if isinstance(sub, ast.FunctionDef):
                        funcs[(node.name, sub.name)] = _Func(path, sub,
                                                             lines)
    return funcs


def _call_sites(cls: Optional[str], fn: ast.FunctionDef,
                attr_classes: Dict[str, str]
                ) -> List[Tuple[Tuple[Optional[str], str], int]]:
    """Resolvable call sites inside ``fn`` with their line numbers:
    ``self.x()`` → same class, ``self.<attr>.x()`` /
    ``<anything>.<attr>.x()`` → attr table."""
    out: List[Tuple[Tuple[Optional[str], str], int]] = []
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        base = node.func.value
        if isinstance(base, ast.Name) and base.id == "self" \
                and cls is not None:
            out.append(((cls, node.func.attr), node.lineno))
        elif isinstance(base, ast.Attribute) \
                and base.attr in attr_classes:
            out.append(((attr_classes[base.attr], node.func.attr),
                        node.lineno))
    return out


def _called_targets(cls: Optional[str], fn: ast.FunctionDef,
                    attr_classes: Dict[str, str]
                    ) -> List[Tuple[Optional[str], str]]:
    return [tgt for tgt, _ in _call_sites(cls, fn, attr_classes)]


def _reachable_hot(funcs, roots, stop, attr_classes
                   ) -> Set[Tuple[Optional[str], str]]:
    """BFS the call graph from ``roots``; do not descend into ``stop``
    entries (retire/oracle — allowed to sync, callees inherit)."""
    seen: Set[Tuple[Optional[str], str]] = set()
    frontier: List[Tuple[Optional[str], str]] = \
        [r for r in roots if r in funcs]
    while frontier:
        key = frontier.pop()
        if key in seen or key in stop:
            continue
        seen.add(key)
        fobj = funcs[key]
        for tgt in _called_targets(key[0], fobj.node, attr_classes):
            if tgt in funcs and tgt not in seen:
                frontier.append(tgt)
    return seen


def _is_jit_decorated(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        expr = dec.func if isinstance(dec, ast.Call) else dec
        # @jax.jit / @jit
        if isinstance(expr, ast.Attribute) and expr.attr == "jit":
            return True
        if isinstance(expr, ast.Name) and expr.id == "jit":
            return True
        # @partial(jax.jit, ...) / @partial(jit, ...)
        if isinstance(dec, ast.Call) and isinstance(dec.func, ast.Name) \
                and dec.func.id == "partial" and dec.args:
            a0 = dec.args[0]
            if (isinstance(a0, ast.Attribute) and a0.attr == "jit") or \
                    (isinstance(a0, ast.Name) and a0.id == "jit"):
                return True
    return False


def _sync_call_kind(node: ast.Call) -> Optional[str]:
    """Classify a call as one of the forbidden blocking constructs."""
    f = node.func
    if not isinstance(f, ast.Attribute):
        return None
    if f.attr == "asarray" and isinstance(f.value, ast.Name) \
            and f.value.id in ("np", "numpy"):
        return "np.asarray"
    if f.attr == "device_get" and isinstance(f.value, ast.Name) \
            and f.value.id == "jax":
        return "jax.device_get"
    if f.attr == "block_until_ready":
        return ".block_until_ready()"
    if f.attr == "item" and not node.args and not node.keywords:
        return ".item()"
    return None


def _annotated_at(lines: List[str], lineno: int,
                  marker: str) -> Optional[int]:
    """The 1-based line carrying ``marker`` if the source line (or the
    line above it — for call expressions wrapped across lines) does."""
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines) and marker in lines[ln - 1]:
            return ln
    return None


def _line_annotated(lines: List[str], lineno: int) -> bool:
    return _annotated_at(lines, lineno, SYNC_OK_ANNOTATION) is not None


def _calls_logger(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id == D2H_LOGGER:
                return True
            if isinstance(f, ast.Attribute) and f.attr == D2H_LOGGER:
                return True
    return False


def _check_hot_function(key, fobj: _Func, jnp_rule: bool
                        ) -> List[Violation]:
    out: List[Violation] = []
    fn, lines = fobj.node, fobj.source_lines
    qn = _qualname(*key)
    jitted = _is_jit_decorated(fn)
    logs = _calls_logger(fn)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        kind = _sync_call_kind(node)
        if kind is not None:
            if _line_annotated(lines, node.lineno):
                if not logs:
                    out.append(Violation(
                        fobj.path, node.lineno, "sync-unlogged",
                        f"{qn}: '{SYNC_OK_ANNOTATION}' site ({kind}) in "
                        f"a function that never calls {D2H_LOGGER} — "
                        "annotated syncs must stay accountable in "
                        "d2h_fetches"))
            else:
                out.append(Violation(
                    fobj.path, node.lineno, "hot-sync",
                    f"{qn}: {kind} in a schedule/submit-phase function "
                    "— blocks the async pipeline; move it to the retire "
                    f"phase or annotate '# {SYNC_OK_ANNOTATION}' and "
                    f"log via {D2H_LOGGER}"))
        if jnp_rule and not jitted \
                and isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == "jnp" \
                and node.func.attr not in JNP_ALLOWED:
            out.append(Violation(
                fobj.path, node.lineno, "jnp-outside-jit",
                f"{qn}: eager jnp.{node.func.attr}() outside jit on the "
                "step path — each eager op is its own device dispatch; "
                "move it inside the jitted step or assemble in numpy"))
    return out


def _check_obs_function(key, fobj: _Func) -> List[Violation]:
    """B4: trace-recording code is append-only plain python — reject
    ANY jax/jnp call and every blocking construct, annotation or not."""
    out: List[Violation] = []
    qn = _qualname(*key)
    for node in ast.walk(fobj.node):
        if not isinstance(node, ast.Call):
            continue
        kind = _sync_call_kind(node)
        if kind is not None:
            out.append(Violation(
                fobj.path, node.lineno, "obs-sync",
                f"{qn}: {kind} in trace-recording code — recording runs "
                "inside schedule/submit phases; it must stay append-only "
                "plain python (no annotation escape — move the work to "
                "repro.obs.export)"))
        root = node.func
        while isinstance(root, ast.Attribute):
            root = root.value
        if isinstance(root, ast.Name) and root.id in ("jax", "jnp"):
            out.append(Violation(
                fobj.path, node.lineno, "obs-jax",
                f"{qn}: {ast.unparse(node.func)}() in trace-recording "
                "code — no jax/jnp calls of any kind (even H2D staging) "
                "belong in the recording path; move the work to "
                "repro.obs.export"))
    return out


def _check_phase_protocol(funcs, hot, retire_only, attr_classes,
                          paths) -> List[Violation]:
    """B5: flag resolvable hot-graph call sites into the RETIRE_ONLY
    table unless waived with ``# phase: retire-ok``, and audit every
    waiver so stale ones cannot silently widen the sanctioned set."""
    out: List[Violation] = []
    used: Set[Tuple[str, int]] = set()
    for key in sorted(hot, key=lambda k: (k[0] or "", k[1])):
        if key in retire_only:
            # retire-only functions may call each other freely
            continue
        fobj = funcs[key]
        qn = _qualname(*key)
        for tgt, lineno in _call_sites(key[0], fobj.node, attr_classes):
            if tgt not in retire_only:
                continue
            ann = _annotated_at(fobj.source_lines, lineno,
                                PHASE_OK_ANNOTATION)
            if ann is not None:
                used.add((fobj.path, ann))
            else:
                out.append(Violation(
                    fobj.path, lineno, "phase-retire-only",
                    f"{qn}: calls retire-only {_qualname(*tgt)} from "
                    "the schedule/submit phase — its bookkeeping needs "
                    "token values the async pipeline has not synced; "
                    "defer it to the retire phase or annotate "
                    f"'# {PHASE_OK_ANNOTATION} (<reason>)' if the "
                    "value dependency is provably satisfied here"))
    for path in paths:
        with open(path) as f:
            lines = f.read().splitlines()
        for i, line in enumerate(lines, start=1):
            if PHASE_OK_ANNOTATION in line and (path, i) not in used:
                out.append(Violation(
                    path, i, "phase-stale",
                    f"'{PHASE_OK_ANNOTATION}' annotation not attached "
                    "to a hot call site of a RETIRE_ONLY function — it "
                    "waives nothing; remove it (or the table entry it "
                    "once waived)"))
    return out


def _check_jitted_time(funcs) -> List[Violation]:
    out: List[Violation] = []
    for key, fobj in funcs.items():
        if not _is_jit_decorated(fobj.node):
            continue
        for node in ast.walk(fobj.node):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id == "time":
                out.append(Violation(
                    fobj.path, node.lineno, "time-in-jit",
                    f"{_qualname(*key)}: time.{node.func.attr}() inside "
                    "a jitted function — traces to a compile-time "
                    "constant, never a measurement"))
    return out


def lint_files(paths: List[str], *,
               kernel_paths: Tuple[str, ...] = (),
               obs_paths: Tuple[str, ...] = (),
               roots: Tuple[Tuple[str, str], ...] = ROOTS,
               retire: Optional[Set[Tuple[str, str]]] = None,
               oracle: Optional[Set[Tuple[str, str]]] = None,
               retire_only: Optional[Set[Tuple[str, str]]] = None,
               attr_classes: Optional[Dict[str, str]] = None
               ) -> List[Violation]:
    """Lint ``paths`` (call-graph rules B1/B2 from ``roots``, phase
    protocol B5 against ``retire_only``) plus ``kernel_paths`` (B1
    everywhere) plus ``obs_paths`` (B4 wholesale — trace recording is
    also indexed into the call graph, so hot-graph ``self.tracer.*``
    calls resolve and get B1/B2 on top) plus B3 over everything."""
    retire = RETIRE_PHASE if retire is None else retire
    oracle = SEQUENTIAL_ORACLE if oracle is None else oracle
    retire_only = RETIRE_ONLY if retire_only is None else retire_only
    attr_classes = ATTR_CLASSES if attr_classes is None else attr_classes
    funcs = _index_functions(list(paths) + list(obs_paths))
    ofuncs = _index_functions(list(obs_paths))
    kfuncs = _index_functions(list(kernel_paths))
    violations: List[Violation] = []
    # phase tables must describe code that exists — a stale entry would
    # silently widen (or shrink) the checked surface
    for label, table in (("retire", retire), ("oracle", oracle),
                         ("retire-only", retire_only),
                         ("root", set(roots))):
        for entry in sorted(table):
            if entry not in funcs:
                violations.append(Violation(
                    "<phase-tables>", 0, "phase-table",
                    f"{label} entry {_qualname(*entry)} not found in the "
                    "scanned sources — update the table"))
    stop = retire | oracle
    hot = _reachable_hot(funcs, roots, stop, attr_classes)
    for key in sorted(hot, key=lambda k: (k[0] or "", k[1])):
        violations.extend(_check_hot_function(key, funcs[key],
                                              jnp_rule=True))
    violations.extend(_check_phase_protocol(funcs, hot, retire_only,
                                            attr_classes, list(paths)))
    for key in sorted(kfuncs, key=lambda k: (k[0] or "", k[1])):
        violations.extend(_check_hot_function(key, kfuncs[key],
                                              jnp_rule=False))
    for key in sorted(ofuncs, key=lambda k: (k[0] or "", k[1])):
        violations.extend(_check_obs_function(key, ofuncs[key]))
    violations.extend(_check_jitted_time({**funcs, **kfuncs}))
    return violations


def lint_tree(src_root: str) -> List[Violation]:
    """Lint the repo's serving + kernels + obs trees with the default
    tables.  ``src_root`` is the directory containing the ``repro``
    package."""
    serving = os.path.join(src_root, "repro", "serving")
    kernels = os.path.join(src_root, "repro", "kernels")
    obs = os.path.join(src_root, "repro", "obs")
    paths = sorted(os.path.join(serving, f) for f in os.listdir(serving)
                   if f.endswith(".py"))
    kpaths = tuple(sorted(os.path.join(kernels, f)
                          for f in os.listdir(kernels)
                          if f.endswith(".py")))
    opaths: Tuple[str, ...] = ()
    if os.path.isdir(obs):
        opaths = tuple(sorted(os.path.join(obs, f)
                              for f in os.listdir(obs)
                              if f.endswith(".py")
                              and f not in OBS_EXPORT_FILES))
    return lint_files(paths, kernel_paths=kpaths, obs_paths=opaths)
