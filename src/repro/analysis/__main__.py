"""CLI for the invariant analyzer: ``python -m repro.analysis``.

Runs the hot-path lint (Pass B) + resource-lifecycle check (Pass C) —
both fast, pure AST — and the compiled-step HLO audit (Pass A: lowers
+ compiles the mixed step per config × mesh).  Exits non-zero on any
violation or fingerprint drift — this is the CI gate.  ``--json``
additionally appends one summary record per static pass to
``analysis_audit.jsonl`` so ``benchmarks/report.py`` can render them.

Must set the XLA host-platform flags BEFORE jax initializes, so the
jax-importing audit module is imported lazily inside ``main``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _repo_paths():
    here = os.path.dirname(os.path.abspath(__file__))   # .../src/repro/analysis
    src = os.path.dirname(os.path.dirname(here))
    return os.path.dirname(src), src                    # (repo root, src/)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant analyzer: compiled-step HLO audit "
                    "+ hot-path lint")
    ap.add_argument("--configs", default=None,
                    help="comma-separated arch names (default: all)")
    ap.add_argument("--meshes", default=None,
                    help="comma-separated mesh names (default: 1x1,2x4)")
    ap.add_argument("--update-goldens", action="store_true",
                    help="rewrite the collective-fingerprint goldens "
                         "instead of diffing against them")
    ap.add_argument("--skip-audit", action="store_true",
                    help="run only the hot-path lint")
    ap.add_argument("--skip-lint", action="store_true",
                    help="run only the compiled-step audit")
    ap.add_argument("--lint-paths", nargs="*", default=None,
                    help="lint these files instead of the repo tree "
                         "(fixture/debug mode)")
    ap.add_argument("--out", default=None,
                    help="results directory for analysis_audit.jsonl + "
                         "analysis_fingerprint_diff.txt + "
                         "analysis_lifecycle.txt (default: "
                         "<repo>/results)")
    ap.add_argument("--json", action="store_true",
                    help="append one summary record per static pass "
                         "(hotpath_lint, lifecycle_check) to "
                         "analysis_audit.jsonl for benchmarks/report.py")
    args = ap.parse_args(argv)

    repo_root, src = _repo_paths()
    out_dir = args.out or os.path.join(repo_root, "results")
    failed = False

    if not args.skip_lint:
        from repro.analysis.hotpath_lint import lint_files, lint_tree
        from repro.analysis.lifecycle_check import check_files, check_tree
        if args.lint_paths is not None:
            violations = lint_files(list(args.lint_paths))
            lifecycle = check_files(list(args.lint_paths))
        else:
            violations = lint_tree(src)
            lifecycle = check_tree(src)
        for v in violations:
            print(v, file=sys.stderr)
        print(f"[lint] {len(violations)} violation(s)")
        for v in lifecycle:
            print(v, file=sys.stderr)
        print(f"[lifecycle] {len(lifecycle)} violation(s)")
        failed |= bool(violations) or bool(lifecycle)
        # violation artifact for the CI failure upload (removed when
        # clean so a green run never ships a stale red artifact)
        os.makedirs(out_dir, exist_ok=True)
        lpath = os.path.join(out_dir, "analysis_lifecycle.txt")
        if lifecycle:
            with open(lpath, "w") as f:
                f.write("".join(f"{v}\n" for v in lifecycle))
        elif os.path.exists(lpath):
            os.remove(lpath)
        if args.json:
            with open(os.path.join(out_dir, "analysis_audit.jsonl"),
                      "a") as f:
                for kind, vs in (("hotpath_lint", violations),
                                 ("lifecycle_check", lifecycle)):
                    f.write(json.dumps({
                        "kind": kind, "ok": not vs,
                        "n_violations": len(vs),
                        "violations": [str(v) for v in vs],
                    }) + "\n")

    if not args.skip_audit:
        # the 2x4 host mesh needs 8 XLA host devices; both env vars are
        # only honored before first jax init
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        from repro.analysis.step_audit import audit_all
        archs = args.configs.split(",") if args.configs else None
        meshes = args.meshes.split(",") if args.meshes else None
        results = audit_all(archs, meshes,
                            update_goldens=args.update_goldens,
                            progress=lambda msg: print(f"[audit] {msg}",
                                                       flush=True))
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "analysis_audit.jsonl"),
                  "a") as f:
            for r in results:
                f.write(json.dumps(r.to_json()) + "\n")
        diff = "".join(r.fingerprint_diff for r in results)
        diff_path = os.path.join(out_dir,
                                 "analysis_fingerprint_diff.txt")
        if diff:
            with open(diff_path, "w") as f:
                f.write(diff)
            print(diff, file=sys.stderr)
        elif os.path.exists(diff_path):
            os.remove(diff_path)
        bad = [r for r in results if not r.ok]
        for r in bad:
            for v in r.violations:
                print(f"{r.arch} [{r.mesh}]: {v}", file=sys.stderr)
        print(f"[audit] {len(results)} step(s) audited, "
              f"{len(bad)} failing")
        failed |= bool(bad)

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
