"""Compiled-step HLO audit (Pass A of the invariant analyzer).

The serving engine's performance story rests on properties of ONE
compiled artifact: the mixed ragged step (``runner._mixed_impl``).  The
benchmarks measure those properties dynamically; this module verifies
them *statically*, on the post-optimization HLO of the exact lowering
production dispatches (``ModelRunner.lower_mixed`` lowers the same
argument tuple ``submit_batch`` executes).  For every config in
``repro.configs`` × mesh in {single-device, data=2/model=4} we check:

  A1  no host round-trips compiled into the step: no custom-call host
      callbacks (extend ``ALLOWED_CUSTOM_CALLS`` only with a reviewed
      reason), no infeed/outfeed.
  A2  host-bound payload is ids-only: every non-donated ROOT output is
      one of ``b_ssm``/``b_conv``/``sampled``; ``sampled`` is a 1-D s32
      of at most pow2(max_running) elements; no host-bound output has a
      vocab-sized dimension (a (R, vocab) logits output would silently
      multiply per-step D2H traffic by the vocab size).
  A3  pool donation: the K/V pools, the SSM live pools (when the arch
      has SSM layers) and ``tok_buf`` appear in ``input_output_alias``
      — and nothing else does.  Donation is what keeps the pools from
      doubling HBM residency every step.
  A4  collective fingerprint: per-(config, mesh) op counts and result
      bytes from ``parse_collectives`` must match the checked-in golden
      under ``analysis/goldens/`` — any drift (a new all-gather from a
      sharding regression, say) fails with a readable diff.
  A5  hygiene: no f32 ``convert`` of a bf16 param-sized (≥ d_model²
      elements) tensor; no dynamic-shape ops (bounded-dynamic ``[<=``,
      set-dimension-size, dynamic-reshape) — the step must stay fully
      static for the bucketed-shape recompile guarantees.

Async/sync equivalence: batches are captured from an engine running the
production default (async one-step-lookahead); a sync-flavored copy
(``from_buf=None``) must lower to the SAME module text — the two modes
are data, not program, so one compile covers both.  If a future change
ever makes them diverge, both get compiled and their collective
fingerprints must agree.

Import note: importing this module imports jax.  The CLI
(``python -m repro.analysis``) sets ``XLA_FLAGS`` for the 8-device host
platform BEFORE this import; do the same in any new entry point.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import all_configs, get_reduced
from repro.core.alora import AdapterSpec, init_adapter_weights
from repro.launch.hlo_analysis import parse_collectives
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.serving import Engine, EngineConfig
from repro.serving import runner as runner_mod
from repro.serving.runner import MixedBatch, next_pow2

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")
# mesh-name → (data, model) host-mesh axes; None = single device
MESHES: Dict[str, Optional[Tuple[int, int]]] = {"1x1": None, "2x4": (2, 4)}

# output tuple slots of _mixed_impl, in order; the ROOT tuple flattens
# these (None slots contribute no leaf, the scalar-0 SSM boundaries of
# attention-only archs contribute one each)
OUT_NAMES = ("k_pool", "v_pool", "live_ssm", "live_conv", "tok_buf",
             "b_ssm", "b_conv", "sampled")
# outputs allowed to stay host-fetchable (everything else must alias)
HOST_PAYLOAD = frozenset({"b_ssm", "b_conv", "sampled"})
# custom-call targets that are NOT host callbacks — any other custom
# call in the step is a finding until reviewed in here.
#   TopK: XLA's device-side top-k expansion (the MoE router's
#   jax.lax.top_k lowers to it on CPU); stays on-device, no host hop.
ALLOWED_CUSTOM_CALLS: Tuple[str, ...] = ("TopK",)
DYNAMIC_SHAPE_MARKERS = ("[<=", " set-dimension-size ",
                         " dynamic-reshape(", " dynamic-reshape ")

_CUSTOM_CALL_RE = re.compile(r'custom_call_target="([^"]+)"')
# `%x = f32[...] convert(bf16[...] %y)` — operand dtype may be inline or
# resolved through the def map when the printer omits operand shapes
_CONVERT_RE = re.compile(
    r"=\s*f32\[([0-9,]*)\]\S*\s+convert\(\s*"
    r"(?:(\w+)\[[0-9,]*\]\S*\s+)?%([\w.\-]+)\)")
_DEF_RE = re.compile(r"%([\w.\-]+)\s*=\s*(\w+)\[([0-9,]*)\]")
_ALIAS_ENTRY_RE = re.compile(r"\{\s*([0-9]+)[0-9,\s]*\}:\s*\((\d+)")


@dataclass
class AuditResult:
    arch: str
    mesh: str
    violations: List[str] = field(default_factory=list)
    fingerprint: Dict[str, Dict] = field(default_factory=dict)
    fingerprint_diff: str = ""
    donated: List[str] = field(default_factory=list)
    sync_async_identical: bool = True
    memory: Dict[str, float] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations and not self.fingerprint_diff

    def to_json(self) -> Dict:
        return {
            "kind": "analysis_audit", "arch": self.arch,
            "mesh": self.mesh, "ok": self.ok,
            "violations": list(self.violations),
            "fingerprint": self.fingerprint,
            "fingerprint_drift": bool(self.fingerprint_diff),
            "donated": list(self.donated),
            "sync_async_identical": self.sync_async_identical,
            "memory": dict(self.memory),
        }


# ---------------------------------------------------------------- text
def entry_body(hlo_text: str) -> str:
    """The ENTRY computation's body.  Inner computations (fusions,
    reducers) have their own ROOT lines — alias/payload checks must only
    ever look at the entry ROOT."""
    m = re.search(r"ENTRY [^{]+\{(.*?)\n\}", hlo_text, re.S)
    return m.group(1) if m else hlo_text


def check_host_callbacks(hlo_text: str) -> List[str]:
    out = []
    for tgt in sorted(set(_CUSTOM_CALL_RE.findall(hlo_text))):
        if tgt not in ALLOWED_CUSTOM_CALLS:
            out.append(f"host-callback: custom_call_target=\"{tgt}\" in "
                       "the compiled step (not in ALLOWED_CUSTOM_CALLS)")
    for marker in ("infeed(", "outfeed("):
        if marker in hlo_text:
            out.append(f"host-callback: {marker[:-1]} op in the compiled "
                       "step")
    return out


def check_dynamic_shapes(hlo_text: str) -> List[str]:
    return [f"dynamic-shape: marker '{m.strip()}' in the compiled step "
            "(bucketed shapes must stay fully static)"
            for m in DYNAMIC_SHAPE_MARKERS if m in hlo_text]


def check_bf16_upcasts(hlo_text: str, threshold_elems: int) -> List[str]:
    """f32 converts of bf16 tensors at/above param size (≥ d_model²
    elements) — a whole-matrix upcast doubles the bandwidth the bf16
    residency was supposed to save."""
    defs = {name: (dt, dims)
            for name, dt, dims in _DEF_RE.findall(hlo_text)}
    out = []
    for dims, op_dtype, op_name in _CONVERT_RE.findall(hlo_text):
        if op_dtype is None or op_dtype == "":
            op_dtype = defs.get(op_name, ("", ""))[0]
        if op_dtype != "bf16":
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        if n >= threshold_elems:
            out.append(f"bf16-upcast: f32[{dims}] convert of bf16 "
                       f"%{op_name} ({n} elems ≥ {threshold_elems}) — "
                       "param-sized tensors must stay bf16 in-step")
    return out


def parse_aliases(hlo_text: str) -> Dict[int, int]:
    """``input_output_alias`` header → {flat output index: param index}.
    The mixed step's ROOT is a flat tuple of arrays, so the alias
    ShapeIndex's leading element IS the flat output index."""
    i = hlo_text.find("input_output_alias={")
    if i < 0:
        return {}
    j, depth = i + len("input_output_alias={"), 1
    while j < len(hlo_text) and depth:
        depth += {"{": 1, "}": -1}.get(hlo_text[j], 0)
        j += 1
    body = hlo_text[i:j]
    return {int(o): int(p) for o, p in _ALIAS_ENTRY_RE.findall(body)}


# ----------------------------------------------------------- step args
def output_leaves(args: Tuple) -> List[Tuple[str, object]]:
    """(slot name, ShapeDtypeStruct) per flat ROOT output, in order."""
    fn = runner_mod._mixed_impl.__wrapped__
    outs = jax.eval_shape(partial(fn, args[0]), *args[1:])
    leaves: List[Tuple[str, object]] = []
    for name, slot in zip(OUT_NAMES, outs):
        for leaf in jax.tree_util.tree_leaves(slot):
            leaves.append((name, leaf))
    return leaves


def check_payload(leaves, aliases: Dict[int, int], cfg,
                  max_running: int) -> List[str]:
    out = []
    for idx, (name, leaf) in enumerate(leaves):
        if idx in aliases:
            continue
        if name not in HOST_PAYLOAD:
            out.append(f"payload: non-donated output #{idx} ({name}, "
                       f"{leaf.dtype}{list(leaf.shape)}) is not part of "
                       "the ids-only host payload")
        if cfg.vocab_size in leaf.shape:
            out.append(f"payload: host-bound output #{idx} ({name}) has "
                       f"a vocab-sized dim {list(leaf.shape)} — logits "
                       "must never leave the device")
        if name == "sampled":
            if str(leaf.dtype) != "int32" or len(leaf.shape) != 1 \
                    or leaf.shape[0] > next_pow2(max_running):
                out.append(f"payload: sampled is {leaf.dtype}"
                           f"{list(leaf.shape)}; expected 1-D int32 of "
                           f"≤ {next_pow2(max_running)} rows")
    return out


def check_donation(leaves, aliases: Dict[int, int],
                   has_ssm: bool) -> Tuple[List[str], List[str]]:
    """All pools aliased, nothing else.  Returns (violations, donated
    output names)."""
    expected = {"k_pool", "v_pool", "tok_buf"}
    if has_ssm:
        expected |= {"live_ssm", "live_conv"}
    out = []
    donated = sorted({leaves[i][0] for i in aliases if i < len(leaves)})
    by_name = {}
    for idx, (name, _) in enumerate(leaves):
        by_name.setdefault(name, []).append(idx)
    for name in sorted(expected):
        idxs = by_name.get(name, [])
        if not idxs:
            out.append(f"donation: expected pool output '{name}' absent "
                       "from the step's ROOT tuple")
        for idx in idxs:
            if idx not in aliases:
                out.append(f"donation: pool output #{idx} ({name}) is "
                           "not in input_output_alias — its HBM doubles "
                           "every step")
    for idx in sorted(aliases):
        name = leaves[idx][0] if idx < len(leaves) else "?"
        if name not in expected:
            out.append(f"donation: unexpected alias of output #{idx} "
                       f"({name}) — only the pools may donate")
    return out, donated


# -------------------------------------------------------- fingerprints
def golden_path(arch: str, mesh_name: str,
                golden_dir: str = GOLDEN_DIR) -> str:
    return os.path.join(golden_dir, f"{arch}__{mesh_name}.json")


def fingerprint_of(hlo_text: str) -> Dict[str, Dict]:
    stats = parse_collectives(hlo_text)
    return {"counts": {k: stats.counts[k] for k in sorted(stats.counts)},
            "result_bytes": {k: int(round(stats.by_kind[k]))
                             for k in sorted(stats.by_kind)}}


# the config knob most likely responsible when a collective kind
# drifts — turns a `--update-goldens` review from HLO archaeology into
# checking one setting
_DRIFT_KNOBS: Dict[str, str] = {
    "all-gather": "EngineConfig.data_shard_tokens / the mesh `data` "
                  "axis (token-axis sharding gathers)",
    "reduce-scatter": "the mesh `model` axis / StepShardings (TP "
                      "matmul partials)",
    "all-reduce": "the mesh `model` axis / StepShardings (TP matmul "
                  "partials)",
    "collective-permute": "StepShardings output layouts (resharding "
                          "between pinned layouts)",
    "all-to-all": "StepShardings output layouts / expert or head "
                  "re-partitioning",
}


def diff_fingerprint(arch: str, mesh_name: str, seen: Dict,
                     golden: Optional[Dict]) -> str:
    """Human-reviewable drift report, grouped per collective op: count
    and result-byte deltas side by side, plus the config knob most
    likely to have moved them."""
    if golden is None:
        return (f"{arch} [{mesh_name}]: no golden checked in at "
                f"{golden_path(arch, mesh_name)} — run "
                "`python -m repro.analysis --update-goldens`\n")
    if seen == golden:
        return ""
    lines = [f"{arch} [{mesh_name}]: collective fingerprint drift"]
    gc, sc = golden.get("counts", {}), seen.get("counts", {})
    gb, sb = golden.get("result_bytes", {}), seen.get("result_bytes", {})
    for kind in sorted(set(gc) | set(sc) | set(gb) | set(sb)):
        c0, c1 = gc.get(kind, 0), sc.get(kind, 0)
        b0, b1 = gb.get(kind, 0), sb.get(kind, 0)
        if c0 == c1 and b0 == b1:
            continue
        if c0 == 0 and b0 == 0:
            what, knob = "NEW op", ("a partitioner/StepShardings "
                                    "change introduced this collective")
        elif c1 == 0 and b1 == 0:
            what, knob = "GONE", ("a partitioner/StepShardings change "
                                  "removed this collective")
        else:
            what = "drifted"
            knob = _DRIFT_KNOBS.get(
                kind, "mesh shape / StepShardings for this op")
        lines.append(f"  {kind:20s} {what:8s} "
                     f"count {c0} -> {c1} ({c1 - c0:+d}), "
                     f"bytes {b0} -> {b1} ({b1 - b0:+d})")
        lines.append(f"  {'':20s} likely knob: {knob}")
    return "\n".join(lines) + "\n"


# ------------------------------------------------------- engine plumbing
def build_engine(arch: str, mesh_name: str) -> Engine:
    mesh = None
    axes = MESHES[mesh_name]
    if axes is not None:
        mesh = make_host_mesh(data=axes[0], model=axes[1])
    cfg = get_reduced(arch)
    params = init_params(jax.random.key(0), cfg)
    ads = [(AdapterSpec("ad0", rank=8, invocation_tokens=(7, 8, 9)),
            init_adapter_weights(jax.random.key(100), cfg, 8))]
    return Engine(cfg, params, adapters=ads,
                  engine_cfg=EngineConfig(max_running=4,
                                          max_batched_tokens=64,
                                          mesh=mesh))


def capture_batch(eng: Engine, n: int = 3, gen: int = 4,
                  plen: int = 24) -> MixedBatch:
    """Run a short production (async) serve and keep the richest
    submitted batch — prefer one mixing decode rows with prefill
    chunks, the shape the steady-state engine dispatches."""
    cfg = eng.cfg
    rng = np.random.RandomState(5)
    captured: List[MixedBatch] = []
    orig = eng.runner.submit_batch

    def cap(mb: MixedBatch):
        captured.append(mb)
        return orig(mb)

    eng.runner.submit_batch = cap  # type: ignore[method-assign]
    try:
        for i in range(n):
            kw = {}
            if cfg.is_encoder_decoder:
                kw = dict(frame_embeds=np.random.RandomState(7).randn(
                    cfg.encoder_seq_len, cfg.d_model).astype(np.float32))
            eng.submit(list(rng.randint(10, 500, plen)), gen,
                       adapter_name="ad0" if i % 2 else None,
                       arrival_time=1e-9 * i, **kw)
        steps = 0
        while (eng.pending or eng.waiting or eng.running) and steps < 60:
            eng.step()
            steps += 1
    finally:
        eng.runner.submit_batch = orig  # type: ignore[method-assign]
    if not captured:
        raise RuntimeError(f"no mixed batch captured for {cfg.name}")
    return max(captured,
               key=lambda mb: (bool(len(mb.block_tables)),
                               len(mb.tok_ids)))


# ------------------------------------------------------------ the audit
def audit_config(arch: str, mesh_name: str, *,
                 golden_dir: str = GOLDEN_DIR,
                 update_goldens: bool = False) -> AuditResult:
    """Compile the production mixed step for (arch, mesh) and run every
    static check.  With ``update_goldens`` the observed collective
    fingerprint is written as the new golden instead of diffed."""
    res = AuditResult(arch=arch, mesh=mesh_name)
    eng = build_engine(arch, mesh_name)
    runner = eng.runner
    mb = capture_batch(eng)

    args = runner._assemble_mixed(mb)
    lowered = runner_mod._mixed_impl.lower(*args)
    # async vs sync is data (from_buf mask), not program: the sync
    # flavor must lower to the identical module
    mb_sync = dataclasses.replace(mb, from_buf=None)
    lowered_sync = runner_mod._mixed_impl.lower(
        *runner._assemble_mixed(mb_sync))
    res.sync_async_identical = \
        lowered.as_text() == lowered_sync.as_text()

    compiled = lowered.compile()
    txt = compiled.as_text()

    res.violations += check_host_callbacks(txt)
    res.violations += check_dynamic_shapes(txt)
    res.violations += check_bf16_upcasts(
        txt, threshold_elems=eng.cfg.d_model * eng.cfg.d_model)

    leaves = output_leaves(args)
    # the alias table sits in the HloModule header (module scope), the
    # ROOT tuple in the ENTRY body — parse from the full text
    aliases = parse_aliases(txt)
    res.violations += check_payload(leaves, aliases, eng.cfg,
                                    runner.rcfg.max_running)
    dviol, res.donated = check_donation(leaves, aliases,
                                        has_ssm=bool(runner.Ls))
    res.violations += dviol

    res.fingerprint = fingerprint_of(txt)
    if not res.sync_async_identical:
        fp_sync = fingerprint_of(lowered_sync.compile().as_text())
        if fp_sync != res.fingerprint:
            res.violations.append(
                "sync-async: the sync-flavored step compiles to a "
                "different collective fingerprint than the async one")
    gp = golden_path(arch, mesh_name, golden_dir)
    if update_goldens:
        os.makedirs(golden_dir, exist_ok=True)
        with open(gp, "w") as f:
            json.dump({"arch": arch, "mesh": mesh_name,
                       **res.fingerprint}, f, indent=2, sort_keys=True)
            f.write("\n")
    else:
        golden: Optional[Dict] = None
        if os.path.exists(gp):
            with open(gp) as f:
                g = json.load(f)
            golden = {"counts": g.get("counts", {}),
                      "result_bytes": g.get("result_bytes", {})}
        res.fingerprint_diff = diff_fingerprint(arch, mesh_name,
                                                res.fingerprint, golden)

    try:
        ma = compiled.memory_analysis()
        res.memory = {
            "alias_size_bytes": float(ma.alias_size_in_bytes),
            "output_size_bytes": float(ma.output_size_in_bytes),
            "temp_size_bytes": float(ma.temp_size_in_bytes),
            "argument_size_bytes": float(ma.argument_size_in_bytes),
        }
    except Exception:        # backend without memory stats: non-fatal
        res.memory = {}
    return res


def audit_all(archs: Optional[List[str]] = None,
              mesh_names: Optional[List[str]] = None, *,
              golden_dir: str = GOLDEN_DIR,
              update_goldens: bool = False,
              progress=None) -> List[AuditResult]:
    archs = sorted(all_configs()) if archs is None else archs
    mesh_names = list(MESHES) if mesh_names is None else mesh_names
    results = []
    for arch in archs:
        for mesh_name in mesh_names:
            if progress:
                progress(f"auditing {arch} [{mesh_name}]")
            results.append(audit_config(arch, mesh_name,
                                        golden_dir=golden_dir,
                                        update_goldens=update_goldens))
    return results
