"""Static invariant analyzer for the serving stack.

Two passes, both gating CI (run `python -m repro.analysis`):

* ``step_audit``   — compiled-step HLO audit (host callbacks, ids-only
  payload, pool donation, collective fingerprints vs goldens, bf16 /
  dynamic-shape hygiene).  NOT imported here: it imports jax, and entry
  points must set ``XLA_FLAGS`` for the 8-device host platform first —
  import ``repro.analysis.step_audit`` directly after doing so.
* ``hotpath_lint`` — AST lint of ``serving/`` + ``kernels/`` enforcing
  the schedule/submit/retire phase discipline (no host syncs or eager
  dispatch on the hot path) and the B5 phase protocol (retire-only
  mutations unreachable from schedule/submit).  Pure stdlib.
* ``lifecycle_check`` — Pass C: path-sensitive resource-lifecycle
  dataflow over ``serving/`` proving every acquire-shaped resource
  (KV blocks, state slots, run slots, adapter pins, staged weights,
  encoder-KV stacks) is released or transferred on every exit path.
  Pure stdlib.

See ``src/repro/analysis/README.md`` for the invariant catalogue.
"""
from repro.analysis.hotpath_lint import Violation, lint_files, lint_tree
from repro.analysis.lifecycle_check import check_files, check_tree

__all__ = ["Violation", "check_files", "check_tree", "lint_files",
           "lint_tree"]
