"""Resource-lifecycle check (Pass C of the invariant analyzer): a
path-sensitive AST dataflow analysis over ``serving/`` proving the
scheduler never leaks an acquire-shaped resource.

Every one of the repo's nastiest historical bugs was a host-side
ownership leak found by hand: the ``_try_admit`` rollback leak (PR 1),
the preempted encoder-KV leak (PR 2), the ``OutOfBlocks`` speculative
block-claim leak (PR 5), and the adapter staging leak + collapsed
prefetch window (PR 9).  This pass makes the whole class a CI failure.

Tracked resources (the acquire table below):

  kv       ``kv_mgr.allocate()`` / ``kv_mgr.acquire(id)`` and the
           ``kv_blocks`` field of ``cache.match_and_acquire``
  state    ``st_mgr.allocate()`` and the ``state_slot`` field of
           ``cache.match_and_acquire`` (optional: may be None)
  adapter  ``adapter_pool.acquire(uid)`` (optional: None on failure)
  runslot  ``self._free_slots.pop()``
  xkv      ``runner.encode(...)`` (the per-request encoder-KV stack)
  staged   a store of non-None to ``<reg>.device_layers`` (the
           staging-tier device copy of prefetched adapter weights)

For every function, every exit path — ``return``, ``raise``, fall off
the end, and ``continue``/``break`` for handles acquired inside the
current loop body — must leave each acquired handle RELEASED (the
paired release call ran) or TRANSFERRED into a recognized owner:

  * a store into an attribute chain (``req.block_ids = ...``,
    ``r.block_ids.append(...)``, ``r.block_ids[b] = canon``,
    ``self._xkv[rid] = ...``) — the object now owns the resource and a
    teardown path is responsible for it (see the teardown table);
  * a ``self._staged[...] = ...`` store (the staging registry claims
    the staged copy; ``tick``/``_drop_stage`` expire it);
  * being returned/yielded (ownership flows to the caller);
  * an explicit ``# owner: <who>`` annotation on the acquire line —
    audited: an ``# owner:`` comment that is not attached to a
    recognized acquire site is itself a violation (``owner-unused``),
    so silenced false positives cannot rot into silenced true ones.

The analysis is optimistic across branch merges (a handle released on
one arm of an ``if`` the analysis cannot correlate — e.g. a rollback
guarded by a bool flag — counts as released) but exact on each exit:
a ``return`` inside a branch is checked with that branch's own state.
Exception edges are approximated per statement: a ``try`` handler sees
the state *before* each simple statement (an acquire that raised never
produced a handle) and *after* each compound one (a partially
completed allocation loop is live in the handler).  Locally defined
closures (the ``bail()`` rollback idiom) are inlined at their call
sites.  ``if x is None`` narrows optional handles out of the true arm.

Two structural checks ride along, covering leaks pure ownership
dataflow cannot express:

  teardown-missing   functions in the teardown table (``_preempt``,
                     ``_finish_requests``) must contain a release of
                     every per-request resource kind — the PR 2
                     encoder-KV leak was exactly a teardown path
                     missing one kind (``_xkv.pop``)
  window-collapse    a loop bound of the occupancy-complement shape
                     (``... - len(...)``) guarding a prefetch/stage
                     call — the PR 9 collapsed prefetch window (a full
                     engine issued zero prefetches); the window must be
                     a config knob, not spare capacity

Tables name code that must exist: a stale entry is a
``lifecycle-table`` violation, so the tables cannot rot.  Fixture
coverage (each historical leak flagged in its pre-fix form, clean in
its fixed form) lives in ``tests/test_analysis.py``.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Set, Tuple

from repro.analysis.hotpath_lint import Violation, _Func, _index_functions, _qualname

OWNER_ANNOTATION = "# owner:"

# statuses a live handle can be in; anything not HELD is safe at exits
HELD = "held"
RELEASED = "released"
TRANSFERRED = "transferred"

# instance-attribute names that identify a resource manager when used
# as a call receiver (directly, or through a local alias like
# ``mgr = self.kv_mgr``)
MANAGER_ATTRS = frozenset({"kv_mgr", "st_mgr", "adapter_pool", "cache",
                           "runner", "_free_slots", "_xkv"})

# (manager, method) → kind of the handle the call creates.  bind_arg
# marks refcount-style acquires (``kv_mgr.acquire(canon)``) where the
# new reference is also bound to the argument name.
_ACQUIRES: Dict[Tuple[str, str], Tuple[str, bool, bool]] = {
    # (manager, method): (kind, optional, bind_arg)
    ("kv_mgr", "allocate"): ("kv", False, False),
    ("kv_mgr", "acquire"): ("kv", False, True),
    ("st_mgr", "allocate"): ("state", False, False),
    ("adapter_pool", "acquire"): ("adapter", True, False),
    ("runner", "encode"): ("xkv", False, False),
    ("_free_slots", "pop"): ("runslot", False, False),
}
# ``cache.match_and_acquire`` returns a match object owning two
# resources, reached through field reads on the result
_BUNDLE_FIELDS: Tuple[Tuple[str, str, bool], ...] = (
    # (field name, kind, optional)
    ("kv_blocks", "kv", False),
    ("state_slot", "state", True),
)

# (manager, method) → value-keyed release: handles bound in the
# argument expressions are released
_RELEASES_BY_VALUE = frozenset({
    ("kv_mgr", "release"), ("kv_mgr", "release_all"),
    ("st_mgr", "release"), ("_free_slots", "append"),
})
# (manager, method) → kind-matched release: releases every held handle
# of the kind (the call is keyed by uid/req-id, not by the handle
# value, so value tracking cannot pair it)
_RELEASES_BY_KIND: Dict[Tuple[str, str], str] = {
    ("adapter_pool", "release"): "adapter",
    ("_xkv", "pop"): "xkv",
}

# per-request teardown functions and the release kinds each MUST
# contain (the encoder-KV leak was _preempt missing the xkv kind)
TEARDOWN_FUNCS: Dict[Tuple[Optional[str], str], FrozenSet[str]] = {
    ("Engine", "_preempt"): frozenset({"kv", "runslot", "adapter",
                                       "xkv"}),
    ("Engine", "_finish_requests"): frozenset({"kv", "runslot",
                                               "adapter", "xkv"}),
}
# calls that consume a prefetch window (the window-collapse check)
_PREFETCH_METHODS = frozenset({"prefetch", "stage", "_stage"})


@dataclass(frozen=True)
class _Handle:
    """One acquire site.  Keyed by site so loop re-executions rebind
    the same summary handle; ``bfield`` tags bundle members so they
    only flow through the matching attribute read."""
    kind: str
    line: int
    bfield: Optional[str] = None
    optional: bool = False


@dataclass
class _State:
    bindings: Dict[str, FrozenSet[_Handle]] = field(default_factory=dict)
    status: Dict[_Handle, str] = field(default_factory=dict)
    aliases: Dict[str, str] = field(default_factory=dict)

    def clone(self) -> "_State":
        return _State(dict(self.bindings), dict(self.status),
                      dict(self.aliases))


def _merge(states: List[_State]) -> _State:
    """Optimistic merge: a handle released or transferred on any arm
    counts as safe; a handle absent from an arm keeps the other arm's
    status (it was never acquired there)."""
    out = _State()
    for st in states:
        for var, hs in st.bindings.items():
            out.bindings[var] = out.bindings.get(var, frozenset()) | hs
        out.aliases.update(st.aliases)
    all_handles: Set[_Handle] = set()
    for st in states:
        all_handles.update(st.status)
    for h in all_handles:
        statuses = [st.status[h] for st in states if h in st.status]
        if TRANSFERRED in statuses:
            out.status[h] = TRANSFERRED
        elif RELEASED in statuses:
            out.status[h] = RELEASED
        else:
            out.status[h] = HELD
    return out


@dataclass
class _Flow:
    """Result of executing a statement list: the fall-through state
    (None if every path terminated) plus states pending at break /
    continue, to be merged at the enclosing loop."""
    out: Optional[_State]
    breaks: List[_State]
    continues: List[_State]


_SIMPLE_STMTS = (ast.Assign, ast.AnnAssign, ast.AugAssign, ast.Expr,
                 ast.Assert, ast.Pass, ast.Import, ast.ImportFrom,
                 ast.Global, ast.Nonlocal, ast.Delete)


class _FunctionChecker:
    """Interprets one function body over the abstract handle domain."""

    def __init__(self, fobj: _Func, qualname: str,
                 violations: List[Violation],
                 owner_used: Set[Tuple[str, int]]) -> None:
        self.path = fobj.path
        self.lines = fobj.source_lines
        self.qn = qualname
        self.violations = violations
        self.owner_used = owner_used
        self.local_defs: Dict[str, ast.FunctionDef] = {}
        self._inline_stack: List[str] = []
        # stack of handle-key snapshots at loop entry — continue/break
        # only leak-check handles acquired inside the current loop body
        self._loop_snapshots: List[Set[_Handle]] = []
        # closure inlining: returns inside an inlined body are not
        # function exits; they accumulate (state, handles) here instead
        self._closure_returns: List[List[Tuple[_State,
                                               FrozenSet[_Handle]]]] = []

    # ---------------------------------------------------------- helpers
    def _owner_annotated(self, lineno: int) -> bool:
        for ln in (lineno, lineno - 1):
            if 1 <= ln <= len(self.lines) \
                    and OWNER_ANNOTATION in self.lines[ln - 1]:
                self.owner_used.add((self.path, ln))
                return True
        return False

    def _manager_of(self, expr: ast.expr, st: _State) -> Optional[str]:
        """Classify a call receiver / store base as a resource manager:
        ``self.kv_mgr`` (any base object), or a local alias of one."""
        if isinstance(expr, ast.Attribute) and expr.attr in MANAGER_ATTRS:
            return expr.attr
        if isinstance(expr, ast.Name):
            return st.aliases.get(expr.id)
        return None

    def _leak_check(self, st: _State, lineno: int, what: str,
                    only: Optional[Set[_Handle]] = None) -> None:
        for h, status in sorted(st.status.items(),
                                key=lambda kv: kv[0].line):
            if status != HELD:
                continue
            if only is not None and h not in only:
                continue
            self.violations.append(Violation(
                self.path, lineno, "leak",
                f"{self.qn}: {h.kind} resource acquired at line "
                f"{h.line} is still held at the {what} on line "
                f"{lineno} — release it, transfer it to an owner "
                f"(Request field / pool registry / return value), or "
                f"annotate the acquire with '{OWNER_ANNOTATION} <who>'"))

    def _exit(self, st: _State, lineno: int, what: str) -> None:
        self._leak_check(st, lineno, what)

    def _loop_local(self, st: _State) -> Optional[Set[_Handle]]:
        if not self._loop_snapshots:
            return set()
        return set(st.status) - self._loop_snapshots[-1]

    # ------------------------------------------------- expression eval
    def _eval(self, expr: Optional[ast.expr], st: _State
              ) -> FrozenSet[_Handle]:
        """Handle-set of an expression, applying acquire/release side
        effects of any calls inside it."""
        if expr is None:
            return frozenset()
        if isinstance(expr, ast.Name):
            return st.bindings.get(expr.id, frozenset())
        if isinstance(expr, ast.Attribute):
            base = self._eval(expr.value, st)
            # bundle members flow through the matching field read only;
            # plain handles never propagate through attribute reads
            return frozenset(h for h in base if h.bfield == expr.attr)
        if isinstance(expr, ast.Call):
            return self._eval_call(expr, st)
        if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
            out: FrozenSet[_Handle] = frozenset()
            for e in expr.elts:
                out |= self._eval(e, st)
            return out
        if isinstance(expr, ast.Dict):
            out = frozenset()
            for k, v in zip(expr.keys, expr.values):
                if k is not None:
                    self._eval(k, st)
                out |= self._eval(v, st)
            return out
        if isinstance(expr, ast.BinOp):
            return self._eval(expr.left, st) | self._eval(expr.right, st)
        if isinstance(expr, ast.BoolOp):
            out = frozenset()
            for v in expr.values:
                out |= self._eval(v, st)
            return out
        if isinstance(expr, ast.IfExp):
            self._eval(expr.test, st)
            return self._eval(expr.body, st) | self._eval(expr.orelse, st)
        if isinstance(expr, ast.UnaryOp):
            return self._eval(expr.operand, st)
        if isinstance(expr, ast.Starred):
            return self._eval(expr.value, st)
        if isinstance(expr, ast.Compare):
            self._eval(expr.left, st)
            for c in expr.comparators:
                self._eval(c, st)
            return frozenset()
        if isinstance(expr, ast.Subscript):
            self._eval(expr.value, st)
            self._eval(expr.slice, st)
            return frozenset()
        if isinstance(expr, (ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp)):
            for gen in expr.generators:
                self._eval(gen.iter, st)
            return frozenset()
        if isinstance(expr, ast.Slice):
            self._eval(expr.lower, st)
            self._eval(expr.upper, st)
            self._eval(expr.step, st)
            return frozenset()
        if isinstance(expr, ast.JoinedStr):
            return frozenset()
        return frozenset()

    def _eval_call(self, call: ast.Call, st: _State
                   ) -> FrozenSet[_Handle]:
        fn = call.func
        arg_handles: List[FrozenSet[_Handle]] = []
        for a in call.args:
            arg_handles.append(self._eval(a, st))
        for kw in call.keywords:
            arg_handles.append(self._eval(kw.value, st))

        if isinstance(fn, ast.Attribute):
            mgr = self._manager_of(fn.value, st)
            key = (mgr, fn.attr) if mgr is not None else None
            if key in _RELEASES_BY_VALUE:
                for hs in arg_handles:
                    for h in hs:
                        if st.status.get(h) == HELD:
                            st.status[h] = RELEASED
                return frozenset()
            if key in _RELEASES_BY_KIND:
                kind = _RELEASES_BY_KIND[key]          # type: ignore[index]
                for h, status in st.status.items():
                    if h.kind == kind and status == HELD:
                        st.status[h] = RELEASED
                return frozenset()
            if key in _ACQUIRES:
                kind, optional, bind_arg = _ACQUIRES[key]  # type: ignore[index]
                h = _Handle(kind, call.lineno, optional=optional)
                st.status[h] = TRANSFERRED \
                    if self._owner_annotated(call.lineno) else HELD
                if bind_arg:
                    for a in call.args:
                        if isinstance(a, ast.Name):
                            st.bindings[a.id] = \
                                st.bindings.get(a.id, frozenset()) \
                                | frozenset({h})
                return frozenset({h})
            if key == ("cache", "match_and_acquire"):
                annotated = self._owner_annotated(call.lineno)
                out: Set[_Handle] = set()
                for bfield, kind, optional in _BUNDLE_FIELDS:
                    h = _Handle(kind, call.lineno, bfield=bfield,
                                optional=optional)
                    st.status[h] = TRANSFERRED if annotated else HELD
                    out.add(h)
                return frozenset(out)
            # list mutators on tracked containers
            if fn.attr in ("append", "extend", "insert", "add"):
                moved = frozenset().union(*arg_handles) \
                    if arg_handles else frozenset()
                if isinstance(fn.value, ast.Name):
                    # local container keeps the binding (release_all on
                    # the container name still pairs with it)
                    var = fn.value.id
                    st.bindings[var] = \
                        st.bindings.get(var, frozenset()) | moved
                else:
                    # attribute-chain container: the object owns it now
                    for h in moved:
                        if st.status.get(h) == HELD:
                            st.status[h] = TRANSFERRED
                return frozenset()
            self._eval(fn.value, st)
            return frozenset().union(*arg_handles) \
                if arg_handles else frozenset()

        if isinstance(fn, ast.Name) and fn.id in self.local_defs \
                and fn.id not in self._inline_stack:
            return self._inline_closure(fn.id, st)

        return frozenset().union(*arg_handles) \
            if arg_handles else frozenset()

    def _inline_closure(self, name: str, st: _State
                        ) -> FrozenSet[_Handle]:
        """Interpret a locally defined ``def`` (the ``bail()`` rollback
        idiom) in the caller's state: its releases apply here, its
        internal returns are not function exits."""
        self._inline_stack.append(name)
        self._closure_returns.append([])
        flow = self._exec_stmts(self.local_defs[name].body, st.clone())
        rets = self._closure_returns.pop()
        self._inline_stack.pop()
        outs = [s for s, _ in rets]
        if flow.out is not None:
            outs.append(flow.out)
        merged = _merge(outs) if outs else st.clone()
        st.bindings = merged.bindings
        st.status = merged.status
        st.aliases = merged.aliases
        result: FrozenSet[_Handle] = frozenset()
        for _, hs in rets:
            result |= hs
        return result

    # ------------------------------------------------- store semantics
    def _assign_target(self, target: ast.expr,
                       value_handles: FrozenSet[_Handle],
                       value: Optional[ast.expr], st: _State) -> None:
        if isinstance(target, ast.Name):
            # local rebind; track manager aliases (mgr = self.kv_mgr)
            if isinstance(value, ast.Attribute) \
                    and value.attr in MANAGER_ATTRS:
                st.aliases[target.id] = value.attr
            else:
                st.aliases.pop(target.id, None)
            st.bindings[target.id] = value_handles
            return
        if isinstance(target, (ast.Tuple, ast.List)):
            if isinstance(value, (ast.Tuple, ast.List)) \
                    and len(value.elts) == len(target.elts):
                for t, v in zip(target.elts, value.elts):
                    self._assign_target(t, self._eval(v, st), v, st)
            else:
                for t in target.elts:
                    self._assign_target(t, value_handles, None, st)
            return
        if isinstance(target, ast.Attribute):
            if target.attr == "device_layers":
                if value is not None and isinstance(value, ast.Constant) \
                        and value.value is None:
                    # dropping the staging copy releases it
                    for h, status in st.status.items():
                        if h.kind == "staged" and status == HELD:
                            st.status[h] = RELEASED
                else:
                    # storing a device copy ACQUIRES a staged handle;
                    # only the staging registry (or a None store)
                    # discharges it
                    h = _Handle("staged", target.lineno)
                    st.status[h] = TRANSFERRED \
                        if self._owner_annotated(target.lineno) else HELD
                return
            # store into an object's attribute: the object owns it
            for h in value_handles:
                if st.status.get(h) == HELD:
                    st.status[h] = TRANSFERRED
            return
        if isinstance(target, ast.Subscript):
            base = target.value
            if isinstance(base, ast.Attribute) and base.attr == "_staged":
                # the staging registry claims every held staged copy
                for h, status in st.status.items():
                    if h.kind == "staged" and status == HELD:
                        st.status[h] = TRANSFERRED
            for h in value_handles:
                if st.status.get(h) == HELD:
                    st.status[h] = TRANSFERRED
            return

    # --------------------------------------------------- narrowing
    def _narrow(self, test: ast.expr, st_true: _State, st_false: _State
                ) -> None:
        """``if x is None`` / ``if x is not None`` on a name holding
        OPTIONAL handles: the None arm never acquired them."""
        if not (isinstance(test, ast.Compare) and len(test.ops) == 1
                and isinstance(test.comparators[0], ast.Constant)
                and test.comparators[0].value is None
                and isinstance(test.left, ast.Name)):
            return
        var = test.left.id
        none_state = st_true if isinstance(test.ops[0], ast.Is) \
            else st_false
        for h in none_state.bindings.get(var, frozenset()):
            if h.optional and none_state.status.get(h) == HELD:
                none_state.status[h] = RELEASED

    # --------------------------------------------------- statements
    def _exec_stmts(self, stmts: List[ast.stmt], st: _State) -> _Flow:
        breaks: List[_State] = []
        continues: List[_State] = []
        cur: Optional[_State] = st
        for s in stmts:
            if cur is None:
                break
            flow = self._exec_stmt(s, cur)
            breaks.extend(flow.breaks)
            continues.extend(flow.continues)
            cur = flow.out
        return _Flow(cur, breaks, continues)

    def _exec_stmt(self, s: ast.stmt, st: _State) -> _Flow:
        if isinstance(s, ast.Return):
            hs = self._eval(s.value, st)
            for h in hs:
                if st.status.get(h) == HELD:
                    st.status[h] = TRANSFERRED
            if self._closure_returns:
                self._closure_returns[-1].append((st, hs))
            else:
                self._exit(st, s.lineno, "return")
            return _Flow(None, [], [])
        if isinstance(s, ast.Raise):
            if s.exc is not None:
                self._eval(s.exc, st)
            if not self._closure_returns:
                self._exit(st, s.lineno, "raise")
            return _Flow(None, [], [])
        if isinstance(s, ast.Break):
            self._leak_check(st, s.lineno, "break",
                             only=self._loop_local(st))
            return _Flow(None, [st], [])
        if isinstance(s, ast.Continue):
            self._leak_check(st, s.lineno, "continue",
                             only=self._loop_local(st))
            return _Flow(None, [], [st])
        if isinstance(s, ast.If):
            self._eval(s.test, st)
            st_true, st_false = st.clone(), st.clone()
            self._narrow(s.test, st_true, st_false)
            f_true = self._exec_stmts(s.body, st_true)
            f_false = self._exec_stmts(s.orelse, st_false)
            outs = [f for f in (f_true.out, f_false.out) if f is not None]
            return _Flow(_merge(outs) if outs else None,
                         f_true.breaks + f_false.breaks,
                         f_true.continues + f_false.continues)
        if isinstance(s, (ast.For, ast.AsyncFor)):
            self._check_window_collapse(s, st)
            iter_handles = self._eval(s.iter, st)
            self._assign_target(s.target, iter_handles, None, st)
            return self._exec_loop(s.body, s.orelse, st)
        if isinstance(s, ast.While):
            self._eval(s.test, st)
            return self._exec_loop(s.body, s.orelse, st)
        if isinstance(s, ast.Try):
            return self._exec_try(s, st)
        if isinstance(s, (ast.With, ast.AsyncWith)):
            for item in s.items:
                hs = self._eval(item.context_expr, st)
                if item.optional_vars is not None:
                    self._assign_target(item.optional_vars, hs,
                                        item.context_expr, st)
            return self._exec_stmts(s.body, st)
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if isinstance(s, ast.FunctionDef):
                self.local_defs[s.name] = s
            return _Flow(st, [], [])
        if isinstance(s, ast.Assign):
            hs = self._eval(s.value, st)
            for t in s.targets:
                self._assign_target(t, hs, s.value, st)
            return _Flow(st, [], [])
        if isinstance(s, ast.AnnAssign):
            if s.value is not None:
                hs = self._eval(s.value, st)
                self._assign_target(s.target, hs, s.value, st)
            return _Flow(st, [], [])
        if isinstance(s, ast.AugAssign):
            self._eval(s.value, st)
            return _Flow(st, [], [])
        if isinstance(s, ast.Expr):
            self._eval(s.value, st)
            return _Flow(st, [], [])
        if isinstance(s, ast.Assert):
            self._eval(s.test, st)
            return _Flow(st, [], [])
        if isinstance(s, ast.Delete):
            for t in s.targets:
                if isinstance(t, ast.Name):
                    st.bindings.pop(t.id, None)
            return _Flow(st, [], [])
        return _Flow(st, [], [])

    def _exec_loop(self, body: List[ast.stmt], orelse: List[ast.stmt],
                   st: _State) -> _Flow:
        entry = st.clone()
        self._loop_snapshots.append(set(st.status))
        flow = self._exec_stmts(body, st)
        self._loop_snapshots.pop()
        # after-loop = entry (zero iterations) ∪ one-iteration
        # fall-through ∪ continue states; break states join after else
        outs = [entry] + ([flow.out] if flow.out is not None else []) \
            + flow.continues
        merged = _merge(outs)
        if orelse:
            else_flow = self._exec_stmts(orelse, merged)
            merged = else_flow.out if else_flow.out is not None \
                else merged
        if flow.breaks:
            merged = _merge([merged] + flow.breaks)
        return _Flow(merged, [], [])

    def _exec_try(self, s: ast.Try, st: _State) -> _Flow:
        # handler entry: merge of per-statement contributions — BEFORE
        # simple statements (an acquire that raised never produced its
        # handle), AFTER compound ones (a partial allocation loop is
        # live when the handler runs)
        contributions: List[_State] = [st.clone()]
        breaks: List[_State] = []
        continues: List[_State] = []
        cur: Optional[_State] = st
        for sub in s.body:
            if cur is None:
                break
            if isinstance(sub, _SIMPLE_STMTS):
                contributions.append(cur.clone())
                flow = self._exec_stmt(sub, cur)
            else:
                flow = self._exec_stmt(sub, cur)
                if flow.out is not None:
                    contributions.append(flow.out.clone())
            breaks.extend(flow.breaks)
            continues.extend(flow.continues)
            cur = flow.out
        handler_entry = _merge(contributions)
        outs: List[_State] = []
        if cur is not None:
            if s.orelse:
                else_flow = self._exec_stmts(s.orelse, cur)
                breaks.extend(else_flow.breaks)
                continues.extend(else_flow.continues)
                if else_flow.out is not None:
                    outs.append(else_flow.out)
            else:
                outs.append(cur)
        for handler in s.handlers:
            hst = handler_entry.clone()
            if handler.name is not None:
                hst.bindings[handler.name] = frozenset()
            h_flow = self._exec_stmts(handler.body, hst)
            breaks.extend(h_flow.breaks)
            continues.extend(h_flow.continues)
            if h_flow.out is not None:
                outs.append(h_flow.out)
        merged: Optional[_State] = _merge(outs) if outs else None
        if s.finalbody:
            fin_in = merged if merged is not None else handler_entry
            fin_flow = self._exec_stmts(s.finalbody, fin_in)
            breaks.extend(fin_flow.breaks)
            continues.extend(fin_flow.continues)
            merged = fin_flow.out
        return _Flow(merged, breaks, continues)

    # ------------------------------------------- window-collapse check
    def _check_window_collapse(self, loop: ast.For, st: _State) -> None:
        """Flag a prefetch window computed as an occupancy complement
        (``... - len(...)``): a full engine makes it zero — exactly
        when prefetching for the queue head matters most."""
        bounds: List[ast.expr] = []
        it = loop.iter
        if isinstance(it, ast.Call):
            bounds.extend(it.args)
        else:
            bounds.append(it)
        suspicious = None
        for b in bounds:
            for node in ast.walk(b):
                if isinstance(node, ast.BinOp) \
                        and isinstance(node.op, ast.Sub):
                    has_len = any(
                        isinstance(n, ast.Call)
                        and isinstance(n.func, ast.Name)
                        and n.func.id == "len"
                        for n in ast.walk(node.right))
                    if has_len:
                        suspicious = node
                        break
            if suspicious is not None:
                break
        if suspicious is None:
            return
        for node in ast.walk(loop):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _PREFETCH_METHODS:
                self.violations.append(Violation(
                    self.path, loop.lineno, "window-collapse",
                    f"{self.qn}: prefetch window bounded by an "
                    "occupancy complement ('... - len(...)') — a full "
                    "engine collapses the window to zero exactly when "
                    "the queue-head prefetch matters; bound it by a "
                    "config knob (e.g. admission_window) instead"))
                return

    # ------------------------------------------------------------- run
    def run(self, fn: ast.FunctionDef) -> None:
        st = _State()
        for sub in fn.body:
            if isinstance(sub, ast.FunctionDef):
                self.local_defs[sub.name] = sub
        flow = self._exec_stmts(
            [sub for sub in fn.body
             if not isinstance(sub, ast.FunctionDef)], st)
        if flow.out is not None:
            end = fn.body[-1].end_lineno or fn.body[-1].lineno
            self._exit(flow.out, end, "end of function")


# ---------------------------------------------------------------- checks
def _check_teardown(funcs: Dict[Tuple[Optional[str], str], _Func],
                    teardown: Dict[Tuple[Optional[str], str],
                                   FrozenSet[str]]
                    ) -> List[Violation]:
    out: List[Violation] = []
    for key, kinds in sorted(teardown.items()):
        if key not in funcs:
            out.append(Violation(
                "<lifecycle-tables>", 0, "lifecycle-table",
                f"teardown entry {_qualname(*key)} not found in the "
                "scanned sources — update the table"))
            continue
        fobj = funcs[key]
        found: Set[str] = set()
        for node in ast.walk(fobj.node):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            recv = node.func.value
            mgr = recv.attr if isinstance(recv, ast.Attribute) \
                and recv.attr in MANAGER_ATTRS else None
            method = node.func.attr
            if mgr == "kv_mgr" and method in ("release", "release_all"):
                found.add("kv")
            elif mgr == "st_mgr" and method == "release":
                found.add("state")
            elif mgr == "_free_slots" and method == "append":
                found.add("runslot")
            elif mgr == "adapter_pool" and method == "release":
                found.add("adapter")
            elif mgr == "_xkv" and method == "pop":
                found.add("xkv")
        for kind in sorted(kinds - found):
            out.append(Violation(
                fobj.path, fobj.node.lineno, "teardown-missing",
                f"{_qualname(*key)}: per-request teardown never "
                f"releases the '{kind}' resource — a torn-down request "
                "would pin it for the engine's lifetime (the PR 2 "
                "encoder-KV leak shape)"))
    return out


def _check_owner_honesty(paths: Iterable[str],
                         owner_used: Set[Tuple[str, int]]
                         ) -> List[Violation]:
    out: List[Violation] = []
    for path in paths:
        with open(path) as f:
            lines = f.read().splitlines()
        for i, line in enumerate(lines, start=1):
            if OWNER_ANNOTATION in line and (path, i) not in owner_used:
                out.append(Violation(
                    path, i, "owner-unused",
                    f"'{OWNER_ANNOTATION}' annotation not attached to a "
                    "recognized acquire site — it silences nothing; "
                    "remove it or move it onto the acquire line"))
    return out


# ------------------------------------------------------------------ API
def check_files(paths: List[str], *,
                teardown: Optional[Dict[Tuple[Optional[str], str],
                                        FrozenSet[str]]] = None
                ) -> List[Violation]:
    """Run Pass C over ``paths``: the per-function lifecycle dataflow,
    the teardown-coverage check and the ``# owner:`` honesty audit."""
    teardown = TEARDOWN_FUNCS if teardown is None else teardown
    funcs = _index_functions(list(paths))
    violations: List[Violation] = []
    owner_used: Set[Tuple[str, int]] = set()
    for key in sorted(funcs, key=lambda k: (k[0] or "", k[1])):
        fobj = funcs[key]
        checker = _FunctionChecker(fobj, _qualname(*key), violations,
                                   owner_used)
        checker.run(fobj.node)
    violations.extend(_check_teardown(funcs, teardown))
    violations.extend(_check_owner_honesty(paths, owner_used))
    return violations


def check_tree(src_root: str) -> List[Violation]:
    """Run Pass C over the repo's ``serving/`` tree with the default
    tables.  ``src_root`` is the directory containing ``repro``."""
    serving = os.path.join(src_root, "repro", "serving")
    paths = sorted(os.path.join(serving, f) for f in os.listdir(serving)
                   if f.endswith(".py"))
    return check_files(paths)
