"""Synthetic token data pipeline.

Deterministic, shardable stream of language-modeling batches: documents
of random length separated by BOS, next-token labels, loss masking of
padding — everything a real pipeline provides, minus the disk.  (The
paper's experiments also use randomly generated prompts — §4.1 — so a
synthetic stream is faithful, not a shortcut.)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    bos_id: int = 1
    mean_doc_len: int = 256
    seed: int = 0


class SyntheticDataset:
    """Infinite deterministic LM stream.  ``batch(step)`` is a pure
    function of (config, step) so every host/restart sees the same data
    — the property real multi-pod input pipelines need."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.RandomState((c.seed * 1_000_003 + step) % 2**31)
        toks = rng.randint(2, c.vocab_size,
                           size=(c.global_batch, c.seq_len + 1),
                           ).astype(np.int32)
        # sprinkle document boundaries
        n_docs = max(c.seq_len // c.mean_doc_len, 1)
        for b in range(c.global_batch):
            cuts = rng.choice(c.seq_len, size=n_docs, replace=False)
            toks[b, cuts] = c.bos_id
        tokens = toks[:, :-1]
        labels = toks[:, 1:]
        mask = np.ones_like(labels, np.float32)
        return {"tokens": tokens, "labels": labels, "mask": mask}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
