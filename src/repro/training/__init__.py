"""Training substrate: optimizer, data pipeline, train step, checkpoints."""
from repro.training.checkpoint import (restore_checkpoint,  # noqa: F401
                                       save_checkpoint)
from repro.training.data import DataConfig, SyntheticDataset  # noqa: F401
from repro.training.optimizer import (AdamWConfig, AdamWState,  # noqa: F401
                                      adamw_update, init_adamw, lr_at)
from repro.training.train_loop import (TrainState,  # noqa: F401
                                       chunked_ce_loss, init_train_state,
                                       make_train_step)
