"""AdamW optimizer with gradient clipping and LR schedules (pure JAX —
optax is not available in this environment, so the substrate is built
from scratch per the assignment).

Optimizer state is a pytree mirroring the parameters; under the
production mesh the moments inherit the parameter shardings, and the
ZeRO-1 option (``repro.distributed.sharding.zero1_specs``) further shards
them along the data axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array           # ()
    mu: Any                   # first moment  (pytree like params)
    nu: Any                   # second moment (pytree like params)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_frac * lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_adamw(params) -> AdamWState:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=zeros(params), nu=zeros(params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params
                 ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """One AdamW step (fp32 moments, params updated in their own dtype).

    Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                       # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), \
        {"grad_norm": gnorm, "lr": lr}
