"""Training step: chunked-vocab cross-entropy + AdamW, pjit-ready.

``make_train_step`` builds a pure (state, batch) -> (state, stats)
function; ``launch/train.py`` wraps it in jit with mesh shardings.  The
loss is computed **chunked over the sequence** so the (B, S, V) logits
tensor is never materialized — with 256k vocabs at 4k×256 tokens that
tensor would be ~0.5 TB; chunking bounds it to (B, chunk, V).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward_full, logits_for
from repro.models.layers import padded_vocab
from repro.models.model import Runtime
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                      init_adamw)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def chunked_ce_loss(params, cfg: ModelConfig, hidden: jax.Array,
                    labels: jax.Array, mask: jax.Array,
                    chunk: int = 512) -> jax.Array:
    """Cross entropy with seq-chunked logits.  hidden: (B, S, d)."""
    B, S, _ = hidden.shape
    V = padded_vocab(cfg)
    vreal = cfg.vocab_size
    nch = max(S // min(chunk, S), 1)
    ch = S // nch
    h = hidden[:, :nch * ch].reshape(B, nch, ch, -1).swapaxes(0, 1)
    y = labels[:, :nch * ch].reshape(B, nch, ch).swapaxes(0, 1)
    m = mask[:, :nch * ch].reshape(B, nch, ch).swapaxes(0, 1)

    def body(carry, inp):
        hc, yc, mc = inp
        logits = logits_for(params, cfg, hc).astype(jnp.float32)
        # mask the padded vocab tail
        neg = jnp.full((V - vreal,), -1e30, jnp.float32) if V > vreal \
            else None
        if neg is not None:
            logits = logits.at[..., vreal:].set(-1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None],
                                   axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (carry[0] + nll.sum(), carry[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (h, y, m))
    return tot / jnp.maximum(cnt, 1.0)


def make_train_step(cfg: ModelConfig, ocfg: AdamWConfig,
                    rt: Runtime = Runtime(), loss_chunk: int = 512):
    """Returns train_step(state, batch) -> (state, stats)."""

    def loss_fn(params, batch):
        extra = batch.get("extra_embeds")
        hidden, aux, _ = forward_full(params, cfg, batch["tokens"], rt,
                                      extra_embeds=extra)
        # vlm: hidden includes the patch prefix — predictions for text
        # positions only
        if extra is not None and not cfg.is_encoder_decoder:
            hidden = hidden[:, extra.shape[1]:]
        ce = chunked_ce_loss(params, cfg, hidden, batch["labels"],
                             batch["mask"], loss_chunk)
        return ce + aux, (ce, aux)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        (loss, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        new_params, new_opt, stats = adamw_update(
            ocfg, grads, state.opt, state.params)
        stats.update({"loss": loss, "ce": ce, "aux": aux})
        return TrainState(new_params, new_opt), stats

    return train_step


def init_train_state(params) -> TrainState:
    return TrainState(params=params, opt=init_adamw(params))
