"""Checkpointing: flat-key .npz save/restore of arbitrary pytrees.

Deliberately dependency-free (orbax is not available offline); the format
is a single .npz whose keys encode the tree path, plus a tiny JSON
manifest for structure validation.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    with open(path + ".manifest.json", "w") as f:
        json.dump({"step": step, "keys": sorted(flat)}, f)


def restore_checkpoint(path: str, tree_like) -> Any:
    """Restore into the structure of ``tree_like`` (shape-checked)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_spec = _flatten(jax.tree.map(np.asarray, tree_like))
    out_leaves = []
    paths, tdef = jax.tree_util.tree_flatten_with_path(tree_like)
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        assert arr.shape == tuple(np.shape(leaf)), \
            f"{key}: ckpt {arr.shape} vs model {np.shape(leaf)}"
        out_leaves.append(arr)
    return tdef.unflatten(out_leaves)


def checkpoint_step(path: str) -> int:
    with open(path + ".manifest.json") as f:
        return json.load(f)["step"]
