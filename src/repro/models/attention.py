"""Attention implementations.

TPU-adapted: prefill/train attention is a *blocked* (flash-style) online-
softmax scan over query/key blocks so the S×S score matrix is never
materialized — on TPU the block shapes are what a Pallas kernel would tile
into VMEM; lowered under jit the same structure keeps XLA workspace bounded
for 32k-token prefills on the production mesh.

Decode attention reads a dense per-request KV cache (the distributed
``serve_step`` layout).  The paged-block engine path lives in
``repro.kernels.paged_attention`` (Pallas kernel + jnp reference) and is
driven by the serving engine's model runner.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: int = 0,
                    q_offset=0,
                    q_block: int = 512,
                    kv_block: int = 512,
                    skip_masked_blocks: bool = False) -> jax.Array:
    """Blocked online-softmax attention with GQA.

    q: (B, Sq, H, hd);  k, v: (B, Sk, KV, hd);  H % KV == 0.
    ``q_offset``: absolute position of q[0] relative to k[0] (chunked
    prefill continuation).  ``window`` > 0 enables sliding-window masking.
    ``skip_masked_blocks``: skip kv-blocks that are entirely masked for a
    given q-block (causal upper triangle / outside the window) — halves
    the compute of causal prefill (§Perf optimization; baseline keeps the
    full rectangle).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qb = min(q_block, Sq)
    kb = min(kv_block, Sk)
    scale = 1.0 / (hd ** 0.5)

    qp = _pad_to(q, 1, qb)
    kp = _pad_to(k, 1, kb)
    vp = _pad_to(v, 1, kb)
    Sqp, Skp = qp.shape[1], kp.shape[1]
    nq, nk = Sqp // qb, Skp // kb

    qr = qp.reshape(B, nq, qb, KV, G, hd)
    kr = kp.reshape(B, nk, kb, KV, hd)
    vr = vp.reshape(B, nk, kb, KV, hd)

    q_offset = jnp.asarray(q_offset, jnp.int32)

    def one_q_block(iq, q_i):
        # q_i: (B, qb, KV, G, hd)
        qpos = q_offset + iq * qb + jnp.arange(qb, dtype=jnp.int32)

        def kv_step(carry, ik):
            m, l, acc = carry
            k_j = jax.lax.dynamic_index_in_dim(kr, ik, 1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vr, ik, 1, keepdims=False)
            kpos = ik * kb + jnp.arange(kb, dtype=jnp.int32)
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            mask = kpos[None, :] < Sk                     # cut padding
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window > 0:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p,
                            v_j.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        def compute(ik_lo, ik_hi):
            m0 = jnp.full((B, KV, G, qb), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, KV, G, qb), jnp.float32)
            a0 = jnp.zeros((B, KV, G, qb, hd), jnp.float32)
            n_steps = ik_hi - ik_lo
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), ik_lo + jnp.arange(nk))
            return m, l, acc

        if skip_masked_blocks and causal:
            # Only kv blocks with kpos_min <= qpos_max contribute.  Trip
            # count must be static under scan, so we run nk steps but make
            # masked steps cheap via select — instead we bound with a
            # fori_loop whose upper bound is dynamic.
            hi = jnp.minimum(
                (q_offset + (iq + 1) * qb + kb - 1) // kb, nk)
            lo = jnp.where(
                window > 0,
                jnp.maximum((q_offset + iq * qb - window) // kb, 0), 0)

            def body(ik, carry):
                carry, _ = kv_step(carry, ik)
                return carry

            m0 = jnp.full((B, KV, G, qb), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, KV, G, qb), jnp.float32)
            a0 = jnp.zeros((B, KV, G, qb, hd), jnp.float32)
            m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
        else:
            m, l, acc = compute(0, nk)

        l = jnp.where(l == 0.0, 1.0, l)                  # fully-masked rows
        out = acc / l[..., None]                          # (B,KV,G,qb,hd)
        return out.transpose(0, 3, 1, 2, 4)               # (B,qb,KV,G,hd)

    outs = jax.lax.map(lambda args: one_q_block(*args),
                       (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4, 5)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sqp, H, hd)
    return out[:, :Sq].astype(q.dtype)


def _flash_fwd_lse(q, k, v, *, causal, window, q_offset, q_block,
                   kv_block):
    """Forward pass that also returns the log-sum-exp per query row —
    the residual the memory-efficient backward needs."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qb = min(q_block, Sq)
    kb = min(kv_block, Sk)
    scale = 1.0 / (hd ** 0.5)
    qp = _pad_to(q, 1, qb)
    kp = _pad_to(k, 1, kb)
    vp = _pad_to(v, 1, kb)
    Sqp, Skp = qp.shape[1], kp.shape[1]
    nq, nk = Sqp // qb, Skp // kb
    qr = qp.reshape(B, nq, qb, KV, G, hd)
    kr = kp.reshape(B, nk, kb, KV, hd)
    vr = vp.reshape(B, nk, kb, KV, hd)
    q_offset = jnp.asarray(q_offset, jnp.int32)

    def one_q_block(iq, q_i):
        qpos = q_offset + iq * qb + jnp.arange(qb, dtype=jnp.int32)

        def kv_step(carry, ik):
            m, l, acc = carry
            k_j = jax.lax.dynamic_index_in_dim(kr, ik, 1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vr, ik, 1, keepdims=False)
            kpos = ik * kb + jnp.arange(kb, dtype=jnp.int32)
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            mask = kpos[None, :] < Sk
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window > 0:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p,
                            v_j.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nk))
        lsafe = jnp.where(l == 0.0, 1.0, l)
        out = acc / lsafe[..., None]
        lse = m + jnp.log(lsafe)                    # (B,KV,G,qb)
        return out.transpose(0, 3, 1, 2, 4), lse.transpose(0, 3, 1, 2)

    outs, lses = jax.lax.map(lambda a: one_q_block(*a),
                             (jnp.arange(nq),
                              qr.transpose(1, 0, 2, 3, 4, 5)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sqp, H, hd)
    lse = lses.transpose(1, 0, 2, 3, 4).reshape(B, Sqp, KV, G)
    return out[:, :Sq].astype(q.dtype), lse[:, :Sq]


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_remat(q, k, v, causal=True, window=0, q_offset=0,
                          q_block=512, kv_block=512):
    """flash_attention with a memory-efficient custom VJP: the backward
    recomputes attention probabilities block-by-block from (q, k, v,
    out, lse) instead of letting AD save every block's softmax product
    (which costs O(S²) HBM through the layer-scan backward — the
    dominant term in the train_4k memory roofline; §Perf iteration 1).
    """
    out, _ = _flash_fwd_lse(q, k, v, causal=causal, window=window,
                            q_offset=q_offset, q_block=q_block,
                            kv_block=kv_block)
    return out


def _remat_fwd(q, k, v, causal, window, q_offset, q_block, kv_block):
    out, lse = _flash_fwd_lse(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, q_block=q_block,
                              kv_block=kv_block)
    return out, (q, k, v, out, lse)


def _remat_bwd(causal, window, q_offset, q_block, kv_block, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qb = min(q_block, Sq)
    kb = min(kv_block, Sk)
    scale = 1.0 / (hd ** 0.5)
    qp = _pad_to(q, 1, qb)
    kp = _pad_to(k, 1, kb)
    vp = _pad_to(v, 1, kb)
    dop = _pad_to(dout.astype(jnp.float32), 1, qb)
    op = _pad_to(out.astype(jnp.float32), 1, qb)
    lsep = _pad_to(lse, 1, qb)
    Sqp, Skp = qp.shape[1], kp.shape[1]
    nq, nk = Sqp // qb, Skp // kb
    qr = qp.reshape(B, nq, qb, KV, G, hd)
    kr = kp.reshape(B, nk, kb, KV, hd)
    vr = vp.reshape(B, nk, kb, KV, hd)
    dor = dop.reshape(B, nq, qb, KV, G, hd)
    lser = lsep.reshape(B, nq, qb, KV, G)
    # D_i = rowsum(dout * out)
    Dr = (dop * op).sum(-1).reshape(B, nq, qb, KV, G)
    q_offset = jnp.asarray(q_offset, jnp.int32)

    def block_p(iq, ik, q_i, k_j, lse_i):
        """Recompute p for one (q-block, kv-block) pair."""
        qpos = q_offset + iq * qb + jnp.arange(qb, dtype=jnp.int32)
        kpos = ik * kb + jnp.arange(kb, dtype=jnp.int32)
        s = jnp.einsum("bqkgd,bskd->bkgqs", q_i, k_j,
                       preferred_element_type=jnp.float32) * scale
        mask = kpos[None, :] < Sk
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        # p normalized by lse: softmax prob
        return jnp.exp(s - lse_i.transpose(0, 2, 3, 1)[..., None])

    def dq_block(iq, args):
        q_i, do_i, lse_i, D_i = args

        def step(acc, ik):
            k_j = jax.lax.dynamic_index_in_dim(kr, ik, 1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vr, ik, 1, keepdims=False)
            p = block_p(iq, ik, q_i, k_j, lse_i)       # (B,KV,G,qb,kb)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", do_i,
                            v_j.astype(jnp.float32))
            ds = p * (dp - D_i.transpose(0, 2, 3, 1)[..., None])
            acc = acc + jnp.einsum("bkgqs,bskd->bqkgd", ds,
                                   k_j.astype(jnp.float32)) * scale
            return acc, None

        acc0 = jnp.zeros((B, qb, KV, G, hd), jnp.float32)
        acc, _ = jax.lax.scan(step, acc0, jnp.arange(nk))
        return acc

    def dkv_block(ik, _):
        k_j = jax.lax.dynamic_index_in_dim(kr, ik, 1, keepdims=False)
        v_j = jax.lax.dynamic_index_in_dim(vr, ik, 1, keepdims=False)

        def step(carry, iq):
            dk_a, dv_a = carry
            q_i = jax.lax.dynamic_index_in_dim(qr, iq, 1, keepdims=False)
            do_i = jax.lax.dynamic_index_in_dim(dor, iq, 1,
                                                keepdims=False)
            lse_i = jax.lax.dynamic_index_in_dim(lser, iq, 1,
                                                 keepdims=False)
            D_i = jax.lax.dynamic_index_in_dim(Dr, iq, 1, keepdims=False)
            p = block_p(iq, ik, q_i, k_j, lse_i)
            dv_a = dv_a + jnp.einsum("bkgqs,bqkgd->bskd", p, do_i)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", do_i,
                            v_j.astype(jnp.float32))
            ds = p * (dp - D_i.transpose(0, 2, 3, 1)[..., None])
            dk_a = dk_a + jnp.einsum("bkgqs,bqkgd->bskd", ds,
                                     q_i.astype(jnp.float32)) * scale
            return (dk_a, dv_a), None

        z = jnp.zeros((B, kb, KV, hd), jnp.float32)
        (dk_a, dv_a), _ = jax.lax.scan(step, (z, z), jnp.arange(nq))
        return dk_a, dv_a

    dq = jax.lax.map(
        lambda a: dq_block(a[0], a[1:]),
        (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4, 5),
         dor.transpose(1, 0, 2, 3, 4, 5),
         lser.transpose(1, 0, 2, 3, 4), Dr.transpose(1, 0, 2, 3, 4)))
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sqp, H, hd)[:, :Sq]
    dkv = jax.lax.map(lambda ik: dkv_block(ik, None), jnp.arange(nk))
    dk = dkv[0].transpose(1, 0, 2, 3, 4).reshape(B, Skp, KV, hd)[:, :Sk]
    dv = dkv[1].transpose(1, 0, 2, 3, 4).reshape(B, Skp, KV, hd)[:, :Sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_remat.defvjp(_remat_fwd, _remat_bwd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, window: int = 0) -> jax.Array:
    """Single-step decode against a dense KV cache.

    q: (B, 1, H, hd); k_cache/v_cache: (B, S_max, KV, hd).
    ``cache_len``: scalar or (B,) — number of valid tokens INCLUDING the
    one written this step.  For sliding-window archs the cache is a ring
    buffer of length W and every slot < min(cache_len, W) is valid.
    """
    B, Smax, KV, hd = k_cache.shape
    H = q.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        cache_len = jnp.full((B,), cache_len)

    qr = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(Smax, dtype=jnp.int32)
    valid = pos[None, :] < jnp.minimum(cache_len, Smax if window == 0
                                       else min(window, Smax))[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def ragged_paged_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           req_rows: jax.Array, q_lens: jax.Array, *,
                           window: int = 0,
                           impl: str = "ref") -> jax.Array:
    """Mixed-batch attention over the paged pool — the unified serving
    step's attention: every packed token (decode singletons and prefill
    chunks alike) attends over its own request's blocks up to its causal
    length.  The step writes the batch's K/V into the pool *before* this
    runs, so intra-chunk causality falls out of the q_lens mask.

    q: (T, H, hd); k_pool/v_pool: (NB, bs, KV, hd);
    block_tables: (R, nb) int32; req_rows: (T,) int32; q_lens: (T,) int32.

    impl: "ref" (jnp gather path, runs everywhere) | "pallas" (TPU
    kernel) | "pallas_interpret" (kernel in interpret mode, for tests).
    """
    if impl == "ref":
        from repro.kernels.ref import ragged_paged_attention_ref
        return ragged_paged_attention_ref(
            q, k_pool, v_pool, block_tables, req_rows, q_lens,
            window=window)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown ragged-attention impl {impl!r}: "
                         "expected 'ref', 'pallas' or 'pallas_interpret'")
    from repro.kernels.paged_attention import \
        ragged_paged_attention as kernel
    return kernel(q, k_pool, v_pool, block_tables, req_rows, q_lens,
                  window=window, interpret=(impl == "pallas_interpret"))


def cross_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Full (non-causal, unmasked) attention, e.g. decoder→encoder.

    q: (B, Sq, H, hd);  k, v: (B, Sk, KV, hd).
    """
    return flash_attention(q, k, v, causal=False)


def quantize_kv(x: jax.Array):
    """Per-(token, head) int8 symmetric quantization.

    x: (..., KV, hd) -> (int8 values, scales (..., KV) f32).
    §Perf: halves decode-cache bytes (the memory-bound term of the
    decode shapes) at ~1e-2 relative dequant error.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def write_kv_cache(k_cache: jax.Array, v_cache: jax.Array,
                   k_new: jax.Array, v_new: jax.Array, pos, *,
                   window: int = 0):
    """Write one decode step's K/V at ``pos`` (ring-buffer when windowed)."""
    B = k_cache.shape[0]
    Smax = k_cache.shape[1]
    slot = jnp.asarray(pos) % (min(window, Smax) if window > 0 else Smax)
    slot = jnp.broadcast_to(slot, (B,))
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, slot].set(k_new[:, 0])
    v_cache = v_cache.at[bidx, slot].set(v_new[:, 0])
    return k_cache, v_cache
