"""Model zoo: config-driven architectures (dense / MoE / SSM / hybrid /
encoder-decoder / VLM) with shared functional sublayers."""
from repro.models.model import (  # noqa: F401
    Runtime,
    decode_step,
    forward_full,
    init_decode_caches,
    init_params,
    iter_layers,
    logits_for,
    param_specs,
    period_segments,
)
