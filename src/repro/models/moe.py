"""Mixture-of-Experts MLP sublayer.

Two interchangeable implementations:

* ``masked_dense`` — reference: every expert computes every token, masked
  accumulation.  Exact (no capacity drops); used for CPU tests / smoke.
* ``expert_parallel`` — production: experts sharded over the ``model``
  mesh axis via ``shard_map``.  Activations are replicated across the
  model axis between sublayers (Megatron convention), so each expert
  shard *gathers* its own tokens locally (capacity-bounded), runs its
  experts, scatters back, and a single ``psum`` over the model axis
  combines shards — the same collective cost as a dense TP MLP, with no
  all-to-all.  Capacity overflow drops tokens (standard top-k dropping).

Both share the router.  ``masked_dense`` also returns the load-balancing
auxiliary loss used in training.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    std = 0.02
    out_std = 0.02 / math.sqrt(2 * cfg.num_layers)
    p = {
        "router": (jax.random.normal(ks[0], (d, m.num_experts))
                   * std).astype(jnp.float32),
        "w_up": (jax.random.normal(ks[1], (m.num_experts, d, m.d_ff))
                 * std).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (m.num_experts, m.d_ff, d))
                   * out_std).astype(dtype),
    }
    if cfg.activation == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[3], (m.num_experts, d, m.d_ff))
                       * std).astype(dtype)
    return p


def _expert_ffn(p: Params, cfg: ModelConfig, x: jax.Array,
                e_slice=slice(None)) -> jax.Array:
    """x: (E, C, d) -> (E, C, d), expert e applied to row e."""
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, p["w_gate"][e_slice])) \
            * jnp.einsum("ecd,edf->ecf", x, p["w_up"][e_slice])
    else:
        h = jnp.einsum("ecd,edf->ecf", x, p["w_up"][e_slice])
        h = jnp.square(jax.nn.relu(h)) if cfg.activation == "squared_relu" \
            else jax.nn.gelu(h, approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"][e_slice])


def route(p: Params, cfg: ModelConfig, x: jax.Array):
    """Router: returns (weights (..., k), idx (..., k), aux_loss)."""
    m = cfg.moe
    logits = x.astype(jnp.float32) @ p["router"]           # (..., E)
    gates = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(gates, m.experts_per_token)
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    pe = gates.mean(axis=tuple(range(gates.ndim - 1)))     # (E,)
    onehot = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32).sum(-2)
    fe = onehot.mean(axis=tuple(range(onehot.ndim - 1)))
    aux = m.num_experts * jnp.sum(fe * pe) * m.load_balance_coef
    return weights, idx, aux


def moe_masked_dense(p: Params, cfg: ModelConfig, x: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """Reference impl: (B, S, d) -> (B, S, d), exact, E× compute."""
    m = cfg.moe
    weights, idx, aux = route(p, cfg, x)

    def body(acc, inp):
        e = inp["_e"]
        sel = (idx == e).astype(jnp.float32) * weights     # (..., k)
        w_tok = sel.sum(-1).astype(x.dtype)[..., None]     # (..., 1)
        we = {k: v[None] for k, v in inp.items() if k != "_e"}
        ye = _expert_ffn(we, cfg, x.reshape(1, -1, x.shape[-1]))
        # routing weight scales the expert OUTPUT (FFN is nonlinear)
        return acc + ye.reshape(x.shape) * w_tok, None

    xs = {k: v for k, v in p.items() if k != "router"}
    xs["_e"] = jnp.arange(m.num_experts)
    acc0 = jnp.zeros_like(x)
    out, _ = jax.lax.scan(body, acc0, xs)
    return out, aux


def moe_expert_parallel(p: Params, cfg: ModelConfig, x: jax.Array, *,
                        mesh: jax.sharding.Mesh,
                        batch_axes: Tuple[str, ...],
                        model_axis: str,
                        capacity_factor: float = 1.25
                        ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel impl under shard_map.  x: (B, S, d)."""
    m = cfg.moe
    E = m.num_experts
    model_size = mesh.shape[model_axis]
    assert E % model_size == 0, (E, model_size)
    e_loc = E // model_size
    # drop batch axes the batch can't shard over (e.g. long_500k B=1:
    # tokens are replicated across `data`; experts still parallel)
    bsz = 1
    for a in batch_axes:
        bsz *= mesh.shape[a]
    if x.shape[0] % max(bsz, 1) != 0:
        batch_axes = ()

    def local(x_loc, router, w_stack):
        # x_loc: (B_loc, S, d) — replicated across the model axis.
        Bl, S, d = x_loc.shape
        T = Bl * S
        xf = x_loc.reshape(T, d)
        p_loc = dict(w_stack)
        p_loc["router"] = router
        weights, idx, aux = route(p_loc, cfg, xf)          # (T,k)
        k = m.experts_per_token
        cap = int(math.ceil(T * k / E * capacity_factor))

        midx = jax.lax.axis_index(model_axis)
        e_lo = midx * e_loc
        flat_e = idx.reshape(-1)                           # (T*k,)
        flat_w = weights.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(T), k)
        # position of each assignment within its expert
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # (T*k, E)
        pos = jnp.cumsum(onehot, axis=0) * onehot              # 1-based
        pos_in_e = (pos.sum(-1) - 1)                           # (T*k,)
        keep = pos_in_e < cap
        mine = (flat_e >= e_lo) & (flat_e < e_lo + e_loc) & keep
        # scatter assignment into (e_loc, cap) slot -> token id (+1), weight
        slot_e = jnp.where(mine, flat_e - e_lo, 0)
        slot_c = jnp.where(mine, pos_in_e, cap)            # cap = dump slot
        tok_buf = jnp.zeros((e_loc, cap + 1), jnp.int32)
        w_buf = jnp.zeros((e_loc, cap + 1), jnp.float32)
        tok_buf = tok_buf.at[slot_e, slot_c].set(
            jnp.where(mine, flat_tok + 1, 0))
        w_buf = w_buf.at[slot_e, slot_c].set(jnp.where(mine, flat_w, 0.0))
        tok_buf = tok_buf[:, :cap]
        w_buf = w_buf[:, :cap]
        valid = tok_buf > 0
        gather_idx = jnp.maximum(tok_buf - 1, 0)           # (e_loc, cap)
        x_e = xf[gather_idx] * valid[..., None].astype(xf.dtype)
        y_e = _local_ffn(w_stack, cfg, x_e)   # w_stack here is the LOCAL shard
        y_e = y_e * w_buf[..., None].astype(y_e.dtype)
        y = jnp.zeros((T, d), x_loc.dtype)
        y = y.at[gather_idx.reshape(-1)].add(
            y_e.reshape(-1, d) * valid.reshape(-1, 1).astype(y_e.dtype))
        y = jax.lax.psum(y, model_axis)
        # aux varies across batch shards (different tokens) — average over
        # the batch axes; it is already invariant along the model axis
        # (router + x are replicated there).
        if batch_axes:
            aux = jax.lax.pmean(aux, tuple(batch_axes))
        return y.reshape(Bl, S, d), aux

    def _local_ffn(w_stack, cfg, x_e):
        if cfg.activation == "swiglu":
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_e, w_stack["w_gate"])) \
                * jnp.einsum("ecd,edf->ecf", x_e, w_stack["w_up"])
        else:
            h = jnp.einsum("ecd,edf->ecf", x_e, w_stack["w_up"])
            h = jnp.square(jax.nn.relu(h)) if cfg.activation == "squared_relu" \
                else jax.nn.gelu(h, approximate=True)
        return jnp.einsum("ecf,efd->ecd", h, w_stack["w_down"])

    w_stack = {k: v for k, v in p.items() if k != "router"}
    bspec = P(batch_axes, None, None)
    wspec = jax.tree.map(lambda _: P(model_axis), w_stack)
    from repro.distributed.sharding import shard_map
    out, aux = shard_map(
        local, mesh=mesh,
        in_specs=(bspec, P(), wspec),
        out_specs=(bspec, P()),
    )(x, p["router"], w_stack)
    return out, aux


def moe_apply(p: Params, cfg: ModelConfig, x: jax.Array, *,
              impl: str = "masked_dense",
              mesh: Optional[jax.sharding.Mesh] = None,
              batch_axes: Tuple[str, ...] = (),
              model_axis: str = "model",
              capacity_factor: float = 1.25
              ) -> Tuple[jax.Array, jax.Array]:
    if impl == "masked_dense":
        return moe_masked_dense(p, cfg, x)
    if impl == "expert_parallel":
        assert mesh is not None
        return moe_expert_parallel(p, cfg, x, mesh=mesh,
                                   batch_axes=batch_axes,
                                   model_axis=model_axis,
                                   capacity_factor=capacity_factor)
    raise ValueError(f"unknown moe impl {impl!r}")
