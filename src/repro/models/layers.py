"""Shared neural-net building blocks (pure JAX, functional).

All functions take explicit parameter pytrees; nothing is stateful.  The
transformer assembly in ``repro.models.model`` composes these; the serving
engine's model runner (``repro.serving.runner``) reuses the same sublayer
functions so the engine and the distributed step functions share one
numerical implementation.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def padded_vocab(cfg: ModelConfig, multiple: int = 512) -> int:
    """Vocab rounded up so embedding/logit matrices shard over the mesh."""
    v = cfg.vocab_size
    return ((v + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32)).astype(orig)


def init_rmsnorm(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype=dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------
def activation_fn(name: str):
    if name == "swiglu":
        raise ValueError("swiglu is handled in mlp_apply (gated)")
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name}")


def init_mlp(key, cfg: ModelConfig, d_ff: int, dtype) -> Params:
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    std = 0.02
    out_std = 0.02 / math.sqrt(2 * cfg.num_layers)
    p = {
        "w_up": (jax.random.normal(k1, (d, d_ff)) * std).astype(dtype),
        "w_down": (jax.random.normal(k2, (d_ff, d)) * out_std).astype(dtype),
    }
    if cfg.activation == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (d, d_ff)) * std).astype(dtype)
    return p


def mlp_apply(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = activation_fn(cfg.activation)(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------
def init_embeddings(key, cfg: ModelConfig, dtype) -> Params:
    v = padded_vocab(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": (jax.random.normal(k1, (v, cfg.d_model)) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(k2, (cfg.d_model, v)) * 0.02
                        ).astype(dtype)
    return p


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return p["tok"][tokens]


def unembed(p: Params, x: jax.Array, tie: bool) -> jax.Array:
    if tie:
        return x @ p["tok"].T
    return x @ p["unembed"]


# ---------------------------------------------------------------------------
# QKV projection with aLoRA activation-aware masking (paper Alg. 1)
# ---------------------------------------------------------------------------
def init_attn(key, cfg: ModelConfig, dtype, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    std = 0.02
    out_std = 0.02 / math.sqrt(2 * cfg.num_layers)
    return {
        "wq": (jax.random.normal(ks[0], (d, H * hd)) * std).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, KV * hd)) * std).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, KV * hd)) * std).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H * hd, d)) * out_std).astype(dtype),
    }


def lora_delta(x: jax.Array, a_stack: jax.Array, b_stack: jax.Array,
               adapter_idx: jax.Array) -> jax.Array:
    """Batched multi-adapter low-rank delta with activation-aware masking.

    This is the TPU-native realization of the paper's Algorithm 1: instead
    of ``base*mask + adapted*(1-mask)``, every token carries an adapter
    index (0 = "no adapter": base tokens AND pre-activation tokens of an
    aLoRA request — the mask of Alg. 1 collapses into index 0), and the
    delta is accumulated per adapter with a masked low-rank matmul.

    x:            (..., T, d)
    a_stack:      (n_adapters, d, r)      — index 0 must be zeros
    b_stack:      (n_adapters, r, out)
    adapter_idx:  (..., T) int32 in [0, n_adapters)
    returns       (..., T, out)
    """
    n = a_stack.shape[0]

    def body(acc, inputs):
        i, a, b = inputs
        sel = (adapter_idx == i)[..., None].astype(x.dtype)
        acc = acc + ((x * sel) @ a) @ b
        return acc, None

    out_dim = b_stack.shape[-1]
    acc0 = jnp.zeros(x.shape[:-1] + (out_dim,), dtype=x.dtype)
    # adapter 0 is the zero adapter; skip it.
    idxs = jnp.arange(1, n)
    acc, _ = jax.lax.scan(body, acc0, (idxs, a_stack[1:], b_stack[1:]))
    return acc


def lora_delta_dispatch(x: jax.Array, a_stack: jax.Array,
                        b_stack: jax.Array, adapter_idx: jax.Array,
                        active_slots: Optional[jax.Array] = None, *,
                        impl: str = "dense") -> jax.Array:
    """Multi-adapter delta with a pluggable implementation (the serving
    engine's ``EngineConfig.mixed_lora_impl``):

    "dense" — :func:`lora_delta`'s stacked scan over EVERY slot in the
    device stack (the pre-pool behavior; equivalence oracle);
    "ref"   — ragged grouped jnp scan over only the step's active slots;
    "pallas"/"pallas_interpret" — the SGMV-style Pallas kernel.

    x / adapter_idx may carry leading batch dims; the grouped paths
    flatten them onto the token axis.
    """
    if impl == "dense" or active_slots is None:
        return lora_delta(x, a_stack, b_stack, adapter_idx)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    idx2 = adapter_idx.reshape(-1)
    if impl == "ref":
        from repro.kernels.ragged_lora import ragged_grouped_lora_ref
        d = ragged_grouped_lora_ref(x2, a_stack, b_stack, idx2,
                                    active_slots)
    elif impl in ("pallas", "pallas_interpret"):
        from repro.kernels.ragged_lora import ragged_grouped_lora_padded
        d = ragged_grouped_lora_padded(
            x2, a_stack, b_stack, idx2, active_slots,
            interpret=(impl == "pallas_interpret"))
    else:
        raise ValueError(f"unknown grouped-LoRA impl {impl!r}: expected "
                         "'dense', 'ref', 'pallas' or 'pallas_interpret'")
    return d.reshape(lead + (d.shape[-1],))


def qkv_project(p: Params, cfg: ModelConfig, x: jax.Array,
                alora: Optional[Params] = None,
                adapter_idx: Optional[jax.Array] = None, *,
                lora_impl: str = "dense",
                active_slots: Optional[jax.Array] = None):
    """Project to q, k, v.  When ``alora`` is given, apply the activation-
    aware masked low-rank update of the paper to each of Q/K/V.

    alora: {"aq","bq","ak","bk","av","bv"} with leading adapter dim.
    ``lora_impl``/``active_slots`` select the grouped ragged delta used
    by the mixed serving step (:func:`lora_delta_dispatch`).
    """
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if alora is not None:
        assert adapter_idx is not None
        q = q + lora_delta_dispatch(x, alora["aq"], alora["bq"],
                                    adapter_idx, active_slots,
                                    impl=lora_impl)
        k = k + lora_delta_dispatch(x, alora["ak"], alora["bk"],
                                    adapter_idx, active_slots,
                                    impl=lora_impl)
        v = v + lora_delta_dispatch(x, alora["av"], alora["bv"],
                                    adapter_idx, active_slots,
                                    impl=lora_impl)
    *lead, _ = x.shape
    q = q.reshape(*lead, cfg.num_heads, cfg.head_dim)
    k = k.reshape(*lead, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(*lead, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def out_project(p: Params, cfg: ModelConfig, attn_out: jax.Array) -> jax.Array:
    *lead, H, hd = attn_out.shape
    return attn_out.reshape(*lead, H * hd) @ p["wo"]
