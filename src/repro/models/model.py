"""Transformer assembly: config-driven model construction.

One implementation serves every assigned architecture:

* homogeneous dense / MoE decoder stacks (stablelm, nemotron, starcoder2,
  minitron, phi-3-vision, phi3.5-moe, granite-moe),
* pure SSM stacks (mamba2),
* periodic hybrid stacks (zamba2: 5×SSM + 1×attn per period),
* encoder-decoder (whisper: bidirectional encoder over stub audio-frame
  embeddings + causal decoder with cross-attention).

Layers are **stacked by period segment and scanned** (``jax.lax.scan``):
the layer pattern is decomposed into its smallest repeating period
(e.g. zamba2: ``(ssm×5, attn×1) × 9``); the outer scan runs over period
repeats, inner scans over the run of each kind.  The lowered HLO contains
each distinct layer body once — essential to keep compile times bounded
when lowering 40-layer models onto a 512-device mesh.

Forward drivers:

* ``forward_full``   — teacher-forced full-sequence pass (train / prefill);
  optionally returns per-layer KV caches + SSM states.
* ``decode_step``    — one-token autoregressive step against dense caches
  (the distributed ``serve_step``; ring-buffer when sliding-window).
* ``iter_layers``    — unstacked per-layer view for the paged serving
  engine's Python-loop model runner.

aLoRA (the paper's technique) threads through every driver as
``(adapters, adapter_idx)``: per-token adapter indices realize the
activation-aware mask of paper Alg. 1 (index 0 = base weights — both
base-model tokens and pre-activation tokens of an aLoRA request).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ATTN, SSM, ModelConfig
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Runtime knobs (distribution / perf) — orthogonal to the architecture.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Runtime:
    moe_impl: str = "masked_dense"        # masked_dense | expert_parallel
    mesh: Optional[jax.sharding.Mesh] = None
    batch_axes: Tuple[str, ...] = ()
    model_axis: str = "model"
    q_block: int = 512
    kv_block: int = 1024
    skip_masked_blocks: bool = False      # §Perf: triangular flash schedule
    capacity_factor: float = 1.25
    remat: bool = False
    window_override: int = 0              # force sliding window (long_500k)
    shard_activations: bool = False
    # unroll layer scans into a python loop — used by the dry-run cost
    # extrapolation (XLA cost_analysis counts a while body ONCE, so
    # scanned-layer FLOPs must be measured on small unrolled variants)
    unroll_layers: bool = False
    # sequence-parallel activations: shard the S axis of residual-stream
    # activations over `model` between blocks (norms/residuals are
    # pointwise).  §Perf optimization for long-sequence training.
    sequence_parallel: bool = False
    # memory-efficient flash backward (custom_vjp, recompute-in-bwd):
    # §Perf iteration 1 — removes the O(S²) softmax-product saves that
    # dominate train_4k temp memory.
    flash_remat: bool = False
    # store decode KV caches in int8 with per-(head,step) scales:
    # §Perf iteration for the memory-bound decode shapes.
    kv_cache_quant: bool = False
    # context-parallel prefill (§Perf iteration 3): residual activations
    # sharded over `model` on the SEQUENCE axis, weights FSDP-sharded
    # over `data` and gathered per layer, attention under shard_map with
    # an all-gathered K/V.  Replaces two per-layer (B,S,d) tensor-parallel
    # all-reduces with one layer-weights all-gather + one (B,S,KV,hd)
    # K/V all-gather — ~2.3× less wire traffic for GQA prefill.
    # Dense decoder-only archs.
    context_parallel: bool = False


def effective_window(cfg: ModelConfig, rt: Runtime) -> int:
    return cfg.sliding_window if cfg.sliding_window else rt.window_override


def _constrain(x, rt: Runtime, spec):
    if rt.shard_activations and rt.mesh is not None:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(rt.mesh, spec))
    return x


def _attn_head_specs(cfg: ModelConfig, rt: Runtime, batch_shardable=True,
                     mode: str = "prefill"):
    """(q_spec, kv_spec) for (B, S, H|KV, hd) activations.

    prefill (compute-heavy, KV transient): shard Q heads over ``model``
    when divisible and REPLICATE K/V there when kv-heads don't divide —
    GQA attention is then fully head-parallel with zero collectives in
    the S×S score path (replicating the small K/V costs one all-gather
    per layer instead of a psum per score block).

    decode (cache-resident): q/k/v adopt the PERSISTENT cache layout —
    kv-heads over ``model`` when both H and KV divide, else head_dim —
    so the cache is never resharded between steps.  Archs whose head
    count doesn't divide the mesh (starcoder2 24H, minitron 24H,
    whisper 20H) fall back to head_dim sharding; the score psum this
    induces is visible in the roofline and is a §Perf item.
    """
    if rt.mesh is None or not rt.shard_activations:
        return None, None
    ms = rt.mesh.shape[rt.model_axis]
    b = rt.batch_axes if batch_shardable else None
    m = rt.model_axis
    heads_ok = cfg.num_heads % ms == 0
    kv_ok = cfg.num_kv_heads % ms == 0
    if mode == "prefill":
        if heads_ok:
            q = P(b, None, m, None)
            kv = P(b, None, m, None) if kv_ok else P(b, None, None, None)
            return q, kv
        assert cfg.head_dim % ms == 0, (cfg.name, cfg.head_dim, ms)
        return P(b, None, None, m), P(b, None, None, m)
    # decode: match the cache layout
    if heads_ok and kv_ok:
        return P(b, None, m, None), P(b, None, m, None)
    assert cfg.head_dim % ms == 0, (cfg.name, cfg.head_dim, ms)
    return P(b, None, None, m), P(b, None, None, m)


# ---------------------------------------------------------------------------
# Period segmentation
# ---------------------------------------------------------------------------
def period_segments(cfg: ModelConfig) -> Tuple[int, List[Tuple[str, int]]]:
    """Smallest repeating period of the layer pattern, run-length encoded.

    Returns (repeats, [(kind, count), ...]) with
    repeats * sum(counts) == num_layers.
    """
    pat = cfg.pattern()
    n = len(pat)
    period = pat
    for p in range(1, n + 1):
        if n % p == 0 and pat == pat[:p] * (n // p):
            period = pat[:p]
            break
    segs: List[Tuple[str, int]] = []
    for kind in period:
        if segs and segs[-1][0] == kind:
            segs[-1] = (kind, segs[-1][1] + 1)
        else:
            segs.append((kind, 1))
    return n // len(period), segs


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: ModelConfig, kind: str, dtype,
                cross: bool = False) -> Params:
    if kind == SSM:
        return {"ln": L.init_rmsnorm(cfg.d_model, dtype),
                "ssm": ssm_lib.init_ssm(key, cfg, dtype)}
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model, dtype),
        "attn": L.init_attn(ks[0], cfg, dtype),
        "ln2": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg, cfg.d_ff, dtype)
    if cross:
        p["xln"] = L.init_rmsnorm(cfg.d_model, dtype)
        p["xattn"] = L.init_attn(ks[2], cfg, dtype)
    return p


def _stack_layers(key, cfg: ModelConfig, kind: str, repeats: int, count: int,
                  dtype, cross: bool = False) -> Params:
    keys = jax.random.split(key, repeats * count)
    ps = [_init_layer(k, cfg, kind, dtype, cross) for k in keys]
    return jax.tree.map(
        lambda *xs: jnp.stack(xs).reshape((repeats, count) + xs[0].shape), *ps)


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = L.dtype_of(cfg)
    repeats, segs = period_segments(cfg)
    k_emb, k_blocks, k_enc = jax.random.split(key, 3)
    seg_keys = jax.random.split(k_blocks, len(segs))
    params: Params = {
        "embed": L.init_embeddings(k_emb, cfg, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "blocks": {
            f"seg{i}": _stack_layers(seg_keys[i], cfg, kind, repeats, count,
                                     dtype,
                                     cross=cfg.is_encoder_decoder
                                     and kind == ATTN)
            for i, (kind, count) in enumerate(segs)
        },
    }
    if cfg.is_encoder_decoder:
        ek = jax.random.split(k_enc, 2)
        params["encoder"] = {
            "blocks": _stack_layers(ek[0], cfg, ATTN, cfg.num_encoder_layers,
                                    1, dtype, cross=False),
            "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        }
    return params


def param_specs(cfg: ModelConfig) -> Params:
    """Abstract parameter tree (no allocation) for dry-run lowering."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


def iter_layers(params: Params, cfg: ModelConfig):
    """Yield (kind, per-layer params) in network order — for the paged
    serving engine's Python-loop runner (reduced models)."""
    repeats, segs = period_segments(cfg)
    for r in range(repeats):
        for si, (kind, count) in enumerate(segs):
            seg = params["blocks"][f"seg{si}"]
            for c in range(count):
                yield kind, jax.tree.map(lambda a: a[r, c], seg)


# ---------------------------------------------------------------------------
# Sublayer applications (shared by all drivers, incl. the paged engine)
# ---------------------------------------------------------------------------
def attn_sublayer_full(lp: Params, cfg: ModelConfig, rt: Runtime,
                       x: jax.Array, positions: jax.Array,
                       alora: Optional[Params], adapter_idx,
                       *, causal: bool = True,
                       return_kv: bool = False):
    """Full-sequence attention sublayer.  x: (B, S, d)."""
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = L.qkv_project(lp["attn"], cfg, h, alora, adapter_idx)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    window = effective_window(cfg, rt) if causal else 0
    if rt.context_parallel and rt.mesh is not None:
        o = _context_parallel_attention(cfg, rt, q, k, v, causal, window)
    else:
        q_spec, kv_spec = _attn_head_specs(cfg, rt)
        if q_spec is not None:
            q = _constrain(q, rt, q_spec)
            k = _constrain(k, rt, kv_spec)
            v = _constrain(v, rt, kv_spec)
        if rt.flash_remat:
            o = attn_lib.flash_attention_remat(
                q, k, v, causal, window, 0, rt.q_block, rt.kv_block)
        else:
            o = attn_lib.flash_attention(
                q, k, v, causal=causal, window=window,
                q_block=rt.q_block, kv_block=rt.kv_block,
                skip_masked_blocks=rt.skip_masked_blocks)
    x = x + L.out_project(lp["attn"], cfg, o)
    if return_kv:
        return x, (k, v)
    return x, None


def _context_parallel_attention(cfg: ModelConfig, rt: Runtime, q, k, v,
                                causal: bool, window: int):
    """Attention with the SEQUENCE axis sharded over ``model``: each
    shard all-gathers K/V (cheap for GQA — KV·hd ≪ d) and runs flash
    over its local query rows at the correct absolute offset."""
    m = rt.model_axis
    b = rt.batch_axes

    def local(q_loc, k_loc, v_loc):
        S_loc = q_loc.shape[1]
        k_full = jax.lax.all_gather(k_loc, m, axis=1, tiled=True)
        v_full = jax.lax.all_gather(v_loc, m, axis=1, tiled=True)
        off = jax.lax.axis_index(m) * S_loc
        return attn_lib.flash_attention(
            q_loc, k_full, v_full, causal=causal, window=window,
            q_offset=off, q_block=rt.q_block, kv_block=rt.kv_block,
            skip_masked_blocks=rt.skip_masked_blocks)

    spec = P(b, m, None, None)
    # check_vma off: flash_attention's scan carries start as invariant
    # zeros, which the varying-axes checker rejects inside shard_map
    from repro.distributed.sharding import shard_map
    return shard_map(local, mesh=rt.mesh,
                     in_specs=(spec, spec, spec),
                     out_specs=spec, check_vma=False)(q, k, v)


def cross_attn_sublayer(lp: Params, cfg: ModelConfig, x: jax.Array,
                        xk: jax.Array, xv: jax.Array):
    """Decoder→encoder cross attention given projected encoder K/V."""
    h = L.rmsnorm(x, lp["xln"], cfg.norm_eps)
    q = (h @ lp["xattn"]["wq"]).reshape(
        h.shape[:-1] + (cfg.num_heads, cfg.head_dim))
    o = attn_lib.cross_attention(q, xk, xv)
    return x + L.out_project(lp["xattn"], cfg, o)


def encoder_kv(lp: Params, cfg: ModelConfig, enc_out: jax.Array):
    """Project encoder output to this decoder layer's cross K/V."""
    B, Se, _ = enc_out.shape
    xk = (enc_out @ lp["xattn"]["wk"]).reshape(
        B, Se, cfg.num_kv_heads, cfg.head_dim)
    xv = (enc_out @ lp["xattn"]["wv"]).reshape(
        B, Se, cfg.num_kv_heads, cfg.head_dim)
    return xk, xv


def mlp_sublayer(lp: Params, cfg: ModelConfig, rt: Runtime, x: jax.Array):
    """MLP / MoE sublayer.  Returns (x, aux_loss)."""
    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe_lib.moe_apply(
            lp["moe"], cfg, h, impl=rt.moe_impl, mesh=rt.mesh,
            batch_axes=rt.batch_axes, model_axis=rt.model_axis,
            capacity_factor=rt.capacity_factor)
    else:
        y, aux = L.mlp_apply(lp["mlp"], cfg, x=h), jnp.zeros((), jnp.float32)
    return x + y, aux


def ssm_sublayer_full(lp: Params, cfg: ModelConfig, x: jax.Array,
                      alora: Optional[Params], adapter_idx,
                      ssm_state=None, conv_state=None):
    h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
    y, st, cv = ssm_lib.ssd_forward(lp["ssm"], cfg, h,
                                    ssm_state=ssm_state,
                                    conv_state=conv_state,
                                    alora=alora, adapter_idx=adapter_idx)
    return x + y, st, cv


# ---------------------------------------------------------------------------
# Scan helpers
# ---------------------------------------------------------------------------
def _scan(body, carry, params_stacked, al_stacked, extra_xs=None,
          unroll: bool = False):
    """scan over the leading axis of params (+ optional adapters/extras).

    body(carry, lp, al, extra) -> (carry, ys)
    ``unroll=True`` runs a python loop instead (dry-run cost analysis).
    """
    if unroll:
        n = jax.tree.leaves(params_stacked)[0].shape[0]
        ys_all = []
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], params_stacked)
            al = None if al_stacked is None else \
                jax.tree.map(lambda a: a[i], al_stacked)
            ex = None if extra_xs is None else \
                jax.tree.map(lambda a: a[i], extra_xs)
            carry, ys = body(carry, lp, al, ex)
            ys_all.append(ys)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ys_all)
        return carry, stacked
    if al_stacked is None and extra_xs is None:
        return jax.lax.scan(lambda c, lp: body(c, lp, None, None),
                            carry, params_stacked)
    if al_stacked is None:
        return jax.lax.scan(lambda c, i: body(c, i[0], None, i[1]),
                            carry, (params_stacked, extra_xs))
    if extra_xs is None:
        return jax.lax.scan(lambda c, i: body(c, i[0], i[1], None),
                            carry, (params_stacked, al_stacked))
    return jax.lax.scan(lambda c, i: body(c, i[0], i[1], i[2]),
                        carry, (params_stacked, al_stacked, extra_xs))


def _seg_tree(tree: Optional[Params], si: int):
    return None if tree is None else tree[f"seg{si}"]


# ---------------------------------------------------------------------------
# forward_full — train / prefill
# ---------------------------------------------------------------------------
def forward_full(params: Params, cfg: ModelConfig, tokens: jax.Array,
                 rt: Runtime = Runtime(), *,
                 positions: Optional[jax.Array] = None,
                 extra_embeds: Optional[jax.Array] = None,
                 adapters: Optional[Params] = None,
                 adapter_idx: Optional[jax.Array] = None,
                 return_caches: bool = False):
    """Teacher-forced pass.

    tokens: (B, S) int32.  ``extra_embeds``:
      * vlm   — (B, num_patches, d) patch embeddings, prepended to the
        token embeddings (ordinary prefix positions);
      * audio — (B, encoder_seq_len, d) frame embeddings, consumed by the
        encoder stack; the decoder cross-attends.

    Returns (hidden (B, S_total, d), aux_loss, caches | None) where
    caches = {"seg{i}": {"k","v"[,"xk","xv"]} | {"ssm","conv"}} with
    leading dims (repeats, count) per segment.
    """
    x = L.embed(params["embed"], tokens)
    enc_out = None
    if cfg.is_encoder_decoder:
        assert extra_embeds is not None, "audio arch needs frame embeddings"
        enc_out = _run_encoder(params["encoder"], cfg, rt, extra_embeds)
    elif extra_embeds is not None:                     # vlm: prepend patches
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        if adapter_idx is not None:
            pad = jnp.zeros(extra_embeds.shape[:2], adapter_idx.dtype)
            adapter_idx = jnp.concatenate([pad, adapter_idx], axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    res_spec = P(rt.batch_axes, rt.model_axis, None) \
        if rt.context_parallel else P(rt.batch_axes, None, None)
    x = _constrain(x, rt, res_spec)

    repeats, segs = period_segments(cfg)

    def layer_body(kind):
        def body(x, lp, al, _):
            if kind == SSM:
                def f(x):
                    x2, st, cv = ssm_sublayer_full(lp, cfg, x, al,
                                                   adapter_idx)
                    return x2, (jnp.zeros((), jnp.float32),
                                {"ssm": st, "conv": cv})
            else:
                def f(x):
                    x2, kv = attn_sublayer_full(lp, cfg, rt, x, positions,
                                                al, adapter_idx,
                                                return_kv=True)
                    cache = {"k": kv[0], "v": kv[1]}
                    if cfg.is_encoder_decoder:
                        xk, xv = encoder_kv(lp, cfg, enc_out)
                        x2 = cross_attn_sublayer(lp, cfg, x2, xk, xv)
                        cache.update({"xk": xk, "xv": xv})
                    x2, aux = mlp_sublayer(lp, cfg, rt, x2)
                    return x2, (aux, cache)
            if rt.remat:
                f = jax.checkpoint(f)
            x, (aux, cache) = f(x)
            x = _constrain(x, rt, res_spec)
            return x, (aux, cache if return_caches else 0)
        return body

    def period_body(x, seg_inputs, _al=None, _ex=None):
        """One period: run each segment's inner scan in order.
        seg_inputs: tuple over segments of (params, adapters|None), each
        leaf with leading dim = count."""
        auxs = jnp.zeros((), jnp.float32)
        seg_caches = []
        for si, (kind, count) in enumerate(segs):
            lp, al = seg_inputs[si]
            x, (a, cs) = _scan(layer_body(kind), x, lp, al,
                               unroll=rt.unroll_layers)
            auxs = auxs + a.sum()
            seg_caches.append(cs)
        return x, (auxs, tuple(seg_caches))

    # xs for the outer (repeats) scan: tuple over segments of (params, al)
    outer_xs = tuple(
        (params["blocks"][f"seg{si}"],
         _seg_tree(adapters, si))
        for si in range(len(segs)))
    if len(segs) == 1 and outer_xs[0][1] is None:
        # fast path: single homogeneous stack — one scan of repeats*count
        kind = segs[0][0]
        flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                            outer_xs[0][0])
        x, (aux, cs) = _scan(layer_body(kind), x, flat, None,
                             unroll=rt.unroll_layers)
        aux_total = aux.sum()
        caches = None
        if return_caches:
            cs = jax.tree.map(
                lambda a: a.reshape((repeats, segs[0][1]) + a.shape[1:]), cs)
            caches = {"seg0": cs}
    else:
        def outer(x, xs):
            return period_body(x, xs)
        if rt.unroll_layers:
            x, (auxs, seg_caches) = _scan(
                lambda c, lp, al, ex: outer(c, lp), x, outer_xs, None,
                unroll=True)
        else:
            x, (auxs, seg_caches) = jax.lax.scan(outer, x, outer_xs)
        aux_total = auxs.sum()
        caches = None
        if return_caches:
            # ys have leading (repeats, count)
            caches = {f"seg{si}": seg_caches[si]
                      for si in range(len(segs))}

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total, caches


def _run_encoder(enc_params: Params, cfg: ModelConfig, rt: Runtime,
                 frames: jax.Array) -> jax.Array:
    """Bidirectional encoder over stub frame embeddings (B, Se, d)."""
    x = frames.astype(L.dtype_of(cfg))
    B, Se, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

    def body(x, lp, al, _):
        x, _ = attn_sublayer_full(lp, cfg, rt, x, positions, None, None,
                                  causal=False)
        x, _ = mlp_sublayer(lp, cfg, rt, x)
        return x, 0

    flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                        enc_params["blocks"])
    x, _ = _scan(body, x, flat, None, unroll=rt.unroll_layers)
    return L.rmsnorm(x, enc_params["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decode_step — one token against dense caches (distributed serve_step)
# ---------------------------------------------------------------------------
def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int,
                       rt: Runtime = Runtime()) -> Params:
    """Allocate (or eval_shape) dense decode caches.

    Attention segments: K/V (repeats, count, B, S_cache, KV, hd) where
    S_cache = min(max_len, window) for sliding-window archs (ring buffer).
    SSM segments: fp32 state (repeats, count, B, nh, N, P) + conv state.
    Encoder-decoder additionally stores projected cross K/V per layer.
    """
    dtype = L.dtype_of(cfg)
    repeats, segs = period_segments(cfg)
    window = effective_window(cfg, rt)
    s_cache = min(max_len, window) if window else max_len
    caches: Params = {}
    for si, (kind, count) in enumerate(segs):
        if kind == SSM:
            s = cfg.ssm
            d_inner, nh, conv_ch = ssm_lib.ssm_dims(cfg)
            caches[f"seg{si}"] = {
                "ssm": jnp.zeros((repeats, count, batch, nh, s.state_dim,
                                  s.head_dim), jnp.float32),
                "conv": jnp.zeros((repeats, count, batch, s.conv_width - 1,
                                   conv_ch), dtype),
            }
        else:
            kv_dtype = jnp.int8 if rt.kv_cache_quant else dtype
            c = {
                "k": jnp.zeros((repeats, count, batch, s_cache,
                                cfg.num_kv_heads, cfg.head_dim), kv_dtype),
                "v": jnp.zeros((repeats, count, batch, s_cache,
                                cfg.num_kv_heads, cfg.head_dim), kv_dtype),
            }
            if rt.kv_cache_quant:
                c["ks"] = jnp.zeros((repeats, count, batch, s_cache,
                                     cfg.num_kv_heads), jnp.float32)
                c["vs"] = jnp.zeros_like(c["ks"])
            if cfg.is_encoder_decoder:
                c["xk"] = jnp.zeros((repeats, count, batch,
                                     cfg.encoder_seq_len, cfg.num_kv_heads,
                                     cfg.head_dim), dtype)
                c["xv"] = jnp.zeros_like(c["xk"])
            caches[f"seg{si}"] = c
    return caches


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                caches: Params, cache_len, rt: Runtime = Runtime(), *,
                adapters: Optional[Params] = None,
                adapter_idx: Optional[jax.Array] = None):
    """One autoregressive step.

    token: (B, 1) int32.  ``cache_len``: scalar int32 — number of tokens
    already in the cache (the new token is written at this position).
    Returns (logits (B, 1, V), new_caches).
    """
    x = L.embed(params["embed"], token)
    B = x.shape[0]
    pos = jnp.asarray(cache_len, jnp.int32)
    positions = jnp.full((B, 1), pos, jnp.int32)
    window = effective_window(cfg, rt)
    repeats, segs = period_segments(cfg)

    def layer_body(kind):
        def body(x, lp, al, cache):
            if kind == SSM:
                h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
                y, st, cv = ssm_lib.ssd_decode_step(
                    lp["ssm"], cfg, h, cache["ssm"], cache["conv"],
                    alora=al, adapter_idx=adapter_idx)
                return x + y, {"ssm": st, "conv": cv}
            h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = L.qkv_project(lp["attn"], cfg, h, al, adapter_idx)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            bsh = x.shape[0] > 1
            q_spec, kv_spec = _attn_head_specs(cfg, rt, bsh, mode="decode")
            if q_spec is not None:
                q = _constrain(q, rt, q_spec)
                k = _constrain(k, rt, kv_spec)
                v = _constrain(v, rt, kv_spec)
            if rt.kv_cache_quant:
                kq, ks = attn_lib.quantize_kv(k)
                vq, vs = attn_lib.quantize_kv(v)
                kc, vc = attn_lib.write_kv_cache(cache["k"], cache["v"],
                                                 kq, vq, pos,
                                                 window=window)
                ksc, vsc = attn_lib.write_kv_cache(
                    cache["ks"][..., None], cache["vs"][..., None],
                    ks[..., None], vs[..., None], pos, window=window)
                ksc, vsc = ksc[..., 0], vsc[..., 0]
                k_de = attn_lib.dequantize_kv(kc, ksc, k.dtype)
                v_de = attn_lib.dequantize_kv(vc, vsc, v.dtype)
                o = attn_lib.decode_attention(q, k_de, v_de, pos + 1,
                                              window=window)
                new_cache = {"k": kc, "v": vc, "ks": ksc, "vs": vsc}
            else:
                kc, vc = attn_lib.write_kv_cache(cache["k"], cache["v"],
                                                 k, v, pos, window=window)
                o = attn_lib.decode_attention(q, kc, vc, pos + 1,
                                              window=window)
                new_cache = {"k": kc, "v": vc}
            x = x + L.out_project(lp["attn"], cfg, o)
            if cfg.is_encoder_decoder:
                x = cross_attn_sublayer(lp, cfg, x, cache["xk"], cache["xv"])
                new_cache.update({"xk": cache["xk"], "xv": cache["xv"]})
            x, _ = mlp_sublayer(lp, cfg, rt, x)
            x = _constrain(x, rt, P(rt.batch_axes, None, None))
            return x, new_cache
        return body

    new_caches: Params = {}
    if len(segs) == 1:
        kind = segs[0][0]
        flat_p = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                              params["blocks"]["seg0"])
        flat_al = None if adapters is None else jax.tree.map(
            lambda a: a.reshape((-1,) + a.shape[2:]), adapters["seg0"])
        flat_c = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                              caches["seg0"])
        x, cs = _scan(layer_body(kind), x, flat_p, flat_al, flat_c,
                      unroll=rt.unroll_layers)
        new_caches["seg0"] = jax.tree.map(
            lambda a: a.reshape((repeats, segs[0][1]) + a.shape[1:]), cs)
    else:
        def outer(x, xs):
            seg_caches = []
            for si, (kind, count) in enumerate(segs):
                lp, al, cache = xs[si]
                x, cs = _scan(layer_body(kind), x, lp, al, cache,
                              unroll=rt.unroll_layers)
                seg_caches.append(cs)
            return x, tuple(seg_caches)

        outer_xs = tuple(
            (params["blocks"][f"seg{si}"], _seg_tree(adapters, si),
             caches[f"seg{si}"])
            for si in range(len(segs)))
        if rt.unroll_layers:
            x, seg_caches = _scan(lambda c, lp, al, ex: outer(c, lp),
                                  x, outer_xs, None, unroll=True)
        else:
            x, seg_caches = jax.lax.scan(outer, x, outer_xs)
        new_caches = {f"seg{si}": seg_caches[si]
                      for si in range(len(segs))}

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_for(params, cfg, x)
    return logits, new_caches


def logits_for(params: Params, cfg: ModelConfig, hidden: jax.Array
               ) -> jax.Array:
    return L.unembed(params["embed"], hidden, cfg.tie_embeddings)


def prefill_to_decode_caches(cfg: ModelConfig, prefill_caches: Params,
                             seq_len: int, max_len: int,
                             rt: Runtime = Runtime()) -> Params:
    """Convert ``forward_full(..., return_caches=True)`` caches into the
    dense decode-cache layout of :func:`init_decode_caches`.

    Full attention: K/V padded out to ``max_len``.  Sliding window: the
    decode cache is a ring buffer of W slots with invariant
    ``slot(p) = p % W``; the last ``min(S, W)`` prefilled tokens are
    scattered to their ring slots.
    """
    window = effective_window(cfg, rt)
    s_cache = min(max_len, window) if window else max_len
    S = seq_len

    def conv_kv(a):
        # a: (repeats, count, B, S, KV, hd)
        if not window or S <= s_cache:
            pad = s_cache - min(S, s_cache)
            out = jnp.zeros(a.shape[:3] + (s_cache,) + a.shape[4:], a.dtype)
            return out.at[:, :, :, :min(S, s_cache)].set(
                a[:, :, :, :s_cache] if S > s_cache else a)
        # windowed, S > W: place token p (p in [S-W, S)) at slot p % W
        tail = a[:, :, :, S - s_cache:]
        pos = jnp.arange(S - s_cache, S)
        slots = pos % s_cache
        out = jnp.zeros(a.shape[:3] + (s_cache,) + a.shape[4:], a.dtype)
        return out.at[:, :, :, slots].set(tail)

    new: Params = {}
    for seg, c in prefill_caches.items():
        if "ssm" in c:
            new[seg] = {"ssm": c["ssm"], "conv": c["conv"]}
        else:
            e = {"k": conv_kv(c["k"]), "v": conv_kv(c["v"])}
            if "xk" in c:
                e.update({"xk": c["xk"], "xv": c["xv"]})
            new[seg] = e
    return new
