"""Mamba2 (SSD — state-space duality) blocks.  [arXiv:2405.21060]

Chunked SSD forward for train/prefill (quadratic within a chunk, linear
across chunks via a ``lax.scan`` recurrence on the (nh, N, P) state) and a
constant-time single-token decode step.

The chunked scan is the TPU adaptation of the paper's GPU kernel: each
chunk's intra-block computation is an MXU-friendly batch of small matmuls
(Q×Q and Q×N×P einsums); the inter-chunk recurrence is a scan carrying the
state — which is also exactly the quantity our serving engine snapshots
for the beyond-paper cross-model *state* reuse (DESIGN.md §2).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.ngroups * s.state_dim
    return d_inner, nheads, conv_ch


def init_ssm(key, cfg: ModelConfig, dtype) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, conv_ch = ssm_dims(cfg)
    ks = jax.random.split(key, 5)
    std = 0.02
    out_std = 0.02 / math.sqrt(2 * cfg.num_layers)
    # dt bias initialized so softplus(dt_bias) spans [1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(ks[3], (nheads,))
                 * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    # The input projection is SPLIT per consumer slice (z / xBC / dt)
    # instead of one fused (d, 2*d_inner + 2*G*N + nheads) matrix: each
    # factor is column-parallel on a dim its consumer reads contiguously,
    # so TP shards never have to reshard the fused dim to recover the
    # slices (the xBC block stays fused — the causal conv consumes it as
    # one contiguous channel block).  Total parameter count is unchanged.
    zk, xk, dk = jax.random.split(ks[0], 3)
    return {
        "in_z": (jax.random.normal(zk, (d, d_inner)) * std).astype(dtype),
        "in_xbc": (jax.random.normal(xk, (d, conv_ch)) * std).astype(dtype),
        "in_dt": (jax.random.normal(dk, (d, nheads)) * std).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_ch))
                   * std).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, nheads + 1, dtype=jnp.float32)
                         ).astype(jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d))
                     * out_std).astype(dtype),
    }


def _causal_conv(xBC: jax.Array, conv_w: jax.Array, conv_b: jax.Array,
                 conv_state: Optional[jax.Array]):
    """Depthwise causal conv along seq.  xBC: (B, S, C); conv_w: (W, C).

    Returns (activated output (B,S,C), new conv_state (B, W-1, C)).
    """
    B, S, C = xBC.shape
    W = conv_w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, W - 1, C), xBC.dtype)
    full = jnp.concatenate([conv_state, xBC], axis=1)      # (B, W-1+S, C)
    # sum_{w} full[:, t + w, :] * conv_w[w]  ->  out[:, t, :]
    out = jnp.zeros((B, S, C), jnp.float32)
    for w in range(W):                                     # W is tiny (4)
        out = out + full[:, w:w + S, :].astype(jnp.float32) * conv_w[w].astype(jnp.float32)
    out = out + conv_b.astype(jnp.float32)
    out = jax.nn.silu(out).astype(xBC.dtype)
    new_state = full[:, S:, :] if S >= W - 1 else full[:, -(W - 1):, :]
    new_state = full[:, -(W - 1):, :]
    return out, new_state


def _rmsnorm_gated(y: jax.Array, z: jax.Array, w: jax.Array,
                   eps: float) -> jax.Array:
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)


def ssd_forward(p: Params, cfg: ModelConfig, x: jax.Array,
                ssm_state: Optional[jax.Array] = None,
                conv_state: Optional[jax.Array] = None,
                alora: Optional[Params] = None,
                adapter_idx: Optional[jax.Array] = None,
                valid_len=None,
                return_boundary_states: bool = False):
    """Chunked SSD over a full sequence.

    x: (B, S, d_model).  Returns (y (B,S,d_model),
    ssm_state (B, nh, N, P) fp32, conv_state (B, W-1, conv_ch)).

    ``alora`` ({"a": (n,d,r), "b": (n,r,in_dim)}) applies the paper's
    activation-aware masked low-rank update to the input projection
    (the fused [z|xBC|dt] delta, sliced onto the split in_z/in_xbc/in_dt
    matmuls) — the SSM analogue of adapting the QKV projections:
    pre-activation tokens
    (adapter index 0) produce *identical* recurrent state to the base
    model, which is what makes the beyond-paper SSM state-snapshot reuse
    sound (DESIGN.md §2).

    ``valid_len`` (scalar): tokens at/after this index are padding — their
    dt is forced to 0 (decay=1, input=0 ⇒ state frozen) and the returned
    conv state is the raw-input window ending at ``valid_len``.

    ``return_boundary_states``: additionally return the SSM state and the
    conv-window state at every chunk boundary — the quantities the
    serving engine snapshots for cross-model state reuse.  With
    ``chunk_size == engine block_size`` the boundaries are exactly the
    KV-block boundaries.
    """
    s = cfg.ssm
    B, S, _ = x.shape
    d_inner, nh, conv_ch = ssm_dims(cfg)
    G, N, P = s.ngroups, s.state_dim, s.head_dim
    hpg = nh // G                                          # heads per group
    Q = min(s.chunk_size, S)

    # split projections: each slice is its own column-parallel matmul
    # (no fused dim for GSPMD to reshard); the adapter delta stays fused
    # over [z|xBC|dt] — its B matrix targets the full in_dim — and is
    # sliced to match
    z = x @ p["in_z"]
    xBC = x @ p["in_xbc"]
    dt = x @ p["in_dt"]                                    # (B,S,nh)
    if alora is not None:
        from repro.models.layers import lora_delta
        delta = lora_delta(x, alora["a"], alora["b"], adapter_idx)
        z = z + delta[..., :d_inner]
        xBC = xBC + delta[..., d_inner:d_inner + conv_ch]
        dt = dt + delta[..., d_inner + conv_ch:]

    seq_valid = None
    if valid_len is not None:
        seq_valid = (jnp.arange(S) < valid_len)            # (S,)
        xBC = xBC * seq_valid[None, :, None].astype(xBC.dtype)

    if conv_state is None:
        conv_state = jnp.zeros((B, s.conv_width - 1, conv_ch), xBC.dtype)
    full_raw = jnp.concatenate([conv_state, xBC], axis=1)  # (B, W-1+S, ch)

    xBC, new_conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                       conv_state)
    if valid_len is not None:
        # conv window ending exactly at valid_len
        new_conv_state = jax.lax.dynamic_slice(
            full_raw, (0, jnp.asarray(valid_len, jnp.int32), 0),
            (B, s.conv_width - 1, conv_ch))
    xs = xBC[..., :d_inner].reshape(B, S, nh, P).astype(jnp.float32)
    Bm = xBC[..., d_inner:d_inner + G * N].reshape(B, S, G, N)
    Cm = xBC[..., d_inner + G * N:].reshape(B, S, G, N)
    # broadcast groups to heads
    Bh = jnp.repeat(Bm, hpg, axis=2).astype(jnp.float32)   # (B,S,nh,N)
    Ch = jnp.repeat(Cm, hpg, axis=2).astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    if seq_valid is not None:
        dt = dt * seq_valid[None, :, None]                 # freeze padding
    A = -jnp.exp(p["A_log"])                               # (nh,)
    dA = dt * A                                            # (B,S,nh) <= 0

    # ---- chunking ----------------------------------------------------------
    pad = (-S) % Q
    if pad:
        z_pad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                                  [(0, 0)] * (t.ndim - 2))
        xs, Bh, Ch, dA, dt = map(z_pad, (xs, Bh, Ch, dA, dt))
    Sp = S + pad
    nc = Sp // Q
    csh = lambda t: t.reshape(B, nc, Q, *t.shape[2:]).swapaxes(0, 1)
    xs_c, Bh_c, Ch_c, dA_c, dt_c = map(csh, (xs, Bh, Ch, dA, dt))
    # shapes: xs_c (nc,B,Q,nh,P), Bh_c/Ch_c (nc,B,Q,nh,N), dA_c (nc,B,Q,nh)

    if ssm_state is None:
        ssm_state = jnp.zeros((B, nh, N, P), jnp.float32)

    def chunk_step(state, inp):
        xc, Bc, Cc, dAc, dtc = inp
        csum = jnp.cumsum(dAc, axis=1)                     # (B,Q,nh)
        total = csum[:, -1]                                # (B,nh)
        # intra-chunk (diagonal blocks):
        # L[q,k] = exp(csum_q - csum_k) for q >= k
        diff = csum[:, :, None, :] - csum[:, None, :, :]   # (B,Q,Q,nh)
        qidx = jnp.arange(Q)
        tri = (qidx[:, None] >= qidx[None, :])
        L = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        CB = jnp.einsum("bqhn,bkhn->bqkh", Cc, Bc)
        W = CB * L * dtc[:, None, :, :]                    # weight (B,Q,Q,nh)
        y_diag = jnp.einsum("bqkh,bkhp->bqhp", W, xc)
        # inter-chunk: contribution of incoming state
        y_off = jnp.einsum("bqhn,bhnp->bqhp", Cc * jnp.exp(csum)[..., None],
                           state)
        # state update for next chunk
        decay_to_end = jnp.exp(total[:, None, :] - csum)   # (B,Q,nh)
        chunk_state = jnp.einsum("bkhn,bkhp->bhnp",
                                 Bc * (dtc * decay_to_end)[..., None], xc)
        new_state = jnp.exp(total)[..., None, None] * state + chunk_state
        return new_state, (y_diag + y_off,
                           new_state if return_boundary_states else 0)

    final_state, (ys, boundary_ssm) = jax.lax.scan(
        chunk_step, ssm_state, (xs_c, Bh_c, Ch_c, dA_c, dt_c))
    y = ys.swapaxes(0, 1).reshape(B, Sp, nh, P)[:, :S]
    y = y + p["D"][:, None] * xs[:, :S]
    y = y.reshape(B, S, d_inner)
    y = _rmsnorm_gated(y, z, p["norm_w"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(jnp.float32)).astype(x.dtype)
    if not return_boundary_states:
        return out, final_state, new_conv_state
    # conv raw-input window ending at each chunk boundary e=(c+1)Q:
    # full_raw[:, e : e + W-1]  (full_raw starts W-1 before token 0)
    W = s.conv_width
    ends = jnp.minimum((jnp.arange(nc) + 1) * Q, S)        # clamp padding
    idx = ends[:, None] + jnp.arange(W - 1)[None, :]       # (nc, W-1)
    boundary_conv = full_raw[:, idx]                       # (B, nc, W-1, ch)
    boundary_conv = boundary_conv.swapaxes(0, 1)           # (nc, B, W-1, ch)
    return out, final_state, new_conv_state, \
        (boundary_ssm, boundary_conv)


def ssd_ragged_forward(p: Params, cfg: ModelConfig, x: jax.Array, *,
                       live_ssm: jax.Array, live_conv: jax.Array,
                       tok_slots: jax.Array, row_cols: jax.Array,
                       seg_ids: jax.Array, snap_rows: jax.Array,
                       last_rows: jax.Array, row_slots: jax.Array,
                       alora: Optional[Params] = None,
                       adapter_idx: Optional[jax.Array] = None,
                       impl: str = "ref",
                       lora_impl: str = "dense",
                       active_slots: Optional[jax.Array] = None):
    """One SSM sublayer over a MIXED RAGGED batch (the unified serving
    step): every scheduled token — decode singletons and prefill chunks
    alike — packed along one token axis, each request's tokens forming a
    contiguous segment that continues from that request's live recurrent
    state.

    x:         (T, d_model) packed hidden rows
    live_ssm:  (MR, nh, N, P) fp32 — per-run-slot recurrent state
    live_conv: (MR, W-1, ch)       — per-run-slot raw conv window
    tok_slots: (T,) int32 — token → its request's run slot
    row_cols:  (T,) int32 — token's offset within its segment (0 = start)
    seg_ids:   (T,) int32 — token → request row (contiguous segments)
    snap_rows: (Cb,) int32 — packed indices of block-boundary tokens
               whose post-token state feeds the prefix cache
    last_rows: (R,) int32 — packed index of each request's final token
    row_slots: (R,) int32 — run slot per request row (scatter-back)
    impl:      "ref" (packed-axis jnp scan) | "pallas" | "pallas_interpret"
    lora_impl/active_slots: grouped-LoRA delta selection for the input-
               projection adapter update (``layers.lora_delta_dispatch``)

    Returns (y (T, d_model), new live_ssm, new live_conv,
             snap_ssm (Cb, nh, N, P) fp32, snap_conv (Cb, W-1, ch)).
    """
    s = cfg.ssm
    T = x.shape[0]
    d_inner, nh, conv_ch = ssm_dims(cfg)
    G, N, P = s.ngroups, s.state_dim, s.head_dim
    hpg = nh // G
    W = s.conv_width

    # split projections (see ssd_forward): per-slice matmuls, fused
    # adapter delta sliced to match
    z = x @ p["in_z"]
    xBC = x @ p["in_xbc"]
    dtr = x @ p["in_dt"]                               # (T, nh)
    if alora is not None:
        from repro.models.layers import lora_delta_dispatch
        delta = lora_delta_dispatch(
            x, alora["a"], alora["b"], adapter_idx, active_slots,
            impl=lora_impl)
        z = z + delta[..., :d_inner]
        xBC = xBC + delta[..., d_inner:d_inner + conv_ch]
        dtr = dtr + delta[..., d_inner + conv_ch:]

    # ---- ragged causal conv -----------------------------------------------
    # Each token's W-wide window spans the previous raw inputs OF ITS OWN
    # SEGMENT; positions before the segment start come from the request's
    # live conv window.  Window col (relative) col' = col - (W-1) + w maps
    # to packed row t - (W-1) + w when col' >= 0 (contiguity), else to
    # live_conv[slot, col' + W-1].
    wj = jnp.arange(W)
    colp = row_cols[:, None] - (W - 1) + wj[None, :]          # (T, W)
    pack_idx = jnp.clip(jnp.arange(T)[:, None] - (W - 1) + wj[None, :],
                        0, T - 1)
    from_pack = xBC[pack_idx]                                 # (T, W, ch)
    conv_rows = live_conv[tok_slots]                          # (T, W-1, ch)
    sidx = jnp.clip(row_cols[:, None] + wj[None, :], 0, W - 2)
    from_state = jnp.take_along_axis(conv_rows, sidx[..., None], axis=1)
    win = jnp.where((colp >= 0)[..., None], from_pack,
                    from_state.astype(xBC.dtype))             # (T, W, ch)
    conv_out = jnp.einsum("twc,wc->tc", win.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) \
        + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out)

    # new conv window per request: raw inputs ending at its last token
    jr = jnp.arange(W - 1)
    span = row_cols[last_rows] + 1                            # (R,)
    colp2 = span[:, None] - (W - 1) + jr[None, :]
    from_pack2 = xBC[jnp.clip(last_rows[:, None] - (W - 2) + jr[None, :],
                              0, T - 1)]
    conv_rows2 = live_conv[row_slots]                         # (R, W-1, ch)
    sidx2 = jnp.clip(span[:, None] + jr[None, :], 0, W - 2)
    from_state2 = jnp.take_along_axis(conv_rows2, sidx2[..., None], axis=1)
    new_rows = jnp.where((colp2 >= 0)[..., None],
                         from_pack2.astype(live_conv.dtype), from_state2)
    new_live_conv = live_conv.at[row_slots].set(new_rows)

    # snapshot conv windows: raw inputs ending AT each boundary token
    csnap = row_cols[snap_rows]
    colp3 = csnap[:, None] - (W - 2) + jr[None, :]
    from_pack3 = xBC[jnp.clip(snap_rows[:, None] - (W - 2) + jr[None, :],
                              0, T - 1)]
    conv_rows3 = live_conv[tok_slots[snap_rows]]
    sidx3 = jnp.clip(csnap[:, None] + 1 + jr[None, :], 0, W - 2)
    from_state3 = jnp.take_along_axis(conv_rows3, sidx3[..., None], axis=1)
    snap_conv = jnp.where((colp3 >= 0)[..., None],
                          from_pack3.astype(live_conv.dtype), from_state3)

    # ---- ragged SSD scan --------------------------------------------------
    xs = conv_out[..., :d_inner].reshape(T, nh, P)
    Bm = conv_out[..., d_inner:d_inner + G * N].reshape(T, G, N)
    Cm = conv_out[..., d_inner + G * N:].reshape(T, G, N)
    Bh = jnp.repeat(Bm, hpg, axis=1)                          # (T, nh, N)
    Ch = jnp.repeat(Cm, hpg, axis=1)
    dtv = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    dA = dtv * (-jnp.exp(p["A_log"]))                         # (T, nh)

    seg_starts = row_cols == 0
    if impl == "ref":
        from repro.kernels.ref import ragged_ssd_scan_ref
        y, states = ragged_ssd_scan_ref(xs, Bh, Ch, dA, dtv, seg_starts,
                                        tok_slots, live_ssm)
    elif impl in ("pallas", "pallas_interpret"):
        from repro.kernels.ops import ragged_ssd_scan_op
        y, states = ragged_ssd_scan_op(
            xs, Bh, Ch, dA, dtv, seg_ids, seg_starts, tok_slots, live_ssm,
            interpret=(impl == "pallas_interpret"))
    else:
        raise ValueError(f"unknown ragged-SSD impl {impl!r}: expected "
                         "'ref', 'pallas' or 'pallas_interpret'")
    new_live_ssm = live_ssm.at[row_slots].set(states[last_rows])
    snap_ssm = states[snap_rows]                              # (Cb, ...)

    y = y.astype(jnp.float32) + p["D"][:, None] * xs
    y = y.reshape(T, d_inner)
    y = _rmsnorm_gated(y, z, p["norm_w"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(jnp.float32)).astype(x.dtype)
    return out, new_live_ssm, new_live_conv, snap_ssm, snap_conv


def ssd_decode_step(p: Params, cfg: ModelConfig, x: jax.Array,
                    ssm_state: jax.Array, conv_state: jax.Array,
                    alora: Optional[Params] = None,
                    adapter_idx: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token recurrence.  x: (B, 1, d_model)."""
    s = cfg.ssm
    B = x.shape[0]
    d_inner, nh, conv_ch = ssm_dims(cfg)
    G, N, P = s.ngroups, s.state_dim, s.head_dim
    hpg = nh // G
    W = s.conv_width

    x0 = x[:, 0]
    z = x0 @ p["in_z"]
    xBC = x0 @ p["in_xbc"]
    dt = x0 @ p["in_dt"]
    if alora is not None:
        from repro.models.layers import lora_delta
        idx = adapter_idx[:, 0] if adapter_idx.ndim == 2 else adapter_idx
        delta = lora_delta(x0, alora["a"], alora["b"], idx)
        z = z + delta[..., :d_inner]
        xBC = xBC + delta[..., d_inner:d_inner + conv_ch]
        dt = dt + delta[..., d_inner + conv_ch:]

    # conv ring: window = [conv_state, xBC]
    full = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) \
        + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out)
    new_conv_state = full[:, 1:, :]

    xt = conv_out[..., :d_inner].reshape(B, nh, P)
    Bt = conv_out[..., d_inner:d_inner + G * N].reshape(B, G, N)
    Ct = conv_out[..., d_inner + G * N:].reshape(B, G, N)
    Bt = jnp.repeat(Bt, hpg, axis=1)                       # (B,nh,N)
    Ct = jnp.repeat(Ct, hpg, axis=1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    dA = jnp.exp(dt * (-jnp.exp(p["A_log"])))              # (B,nh)

    new_state = dA[..., None, None] * ssm_state + \
        jnp.einsum("bhn,bhp->bhnp", Bt * dt[..., None], xt)
    y = jnp.einsum("bhn,bhnp->bhp", Ct, new_state) + p["D"][:, None] * xt
    y = y.reshape(B, d_inner)
    y = _rmsnorm_gated(y, z, p["norm_w"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(jnp.float32)).astype(x.dtype)
    return out[:, None, :], new_state, new_conv_state


def init_ssm_state(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_inner, nh, conv_ch = ssm_dims(cfg)
    return (jnp.zeros((batch, nh, s.state_dim, s.head_dim), jnp.float32),
            jnp.zeros((batch, s.conv_width - 1, conv_ch),
                      jnp.dtype(cfg.dtype)))
