"""Paged model runner — executes the serving engine's jitted steps
against the paged KV pool / SSM state pools.

This is the engine-side analogue of vLLM's GPU model runner (paper §3 +
App. A/B): before each forward it assembles the aLoRA metadata (per-token
adapter indices — the activation-aware mask) and block tables, then runs
a jitted step.  The primary path is ``submit_batch`` + ``fetch_sampled``
— ONE jitted ragged step per engine iteration covering every
architecture family (attention, SSM/hybrid via a ragged SSD scan,
encoder-decoder via per-row cross-attention KV), dispatched without
blocking so the engine can retire it a step later (``execute_batch`` is
the submit-then-fetch sync wrapper); the v0-style
``prefill_chunk``/``decode_batch`` pair is kept for the explicit
sequential mode.  Host-side assembly reuses
persistent capacity-doubling buffers (``HostBufferPool``) instead of
reallocating per step.  The numerical sublayers are shared with the
distributed step functions (``repro.models``); shapes are bucketed
(powers of two) so jit caches a bounded set of traces.  The jitted step
functions are module-level with a hashable static ``RunnerSpec`` so
independent Engine instances over the same config share one compilation
cache (the analogue of vLLM's CUDA-graph reuse across server restarts in
a warm process).

With a mesh (``EngineConfig.mesh``) the SAME single jitted step runs
TP-sharded under GSPMD: params tensor-parallel, the paged K/V pool split
on its KV-head (or head_dim) dim, SSM pools on their head/channel dims,
adapter slot stacks column-parallel on B's output dim, and all per-token
metadata replicated (``distributed.sharding`` §Sharded serving).  The
static ``StepShardings`` in the spec pins output layouts so pools never
reshard between steps; the host-side assembly below is untouched.

Sampling happens ON DEVICE: the mixed step ends in an argmax over the
per-request logits rows and returns only the sampled ``int32`` token ids
— the full ``(R, vocab)`` logits never cross to host.  A device-resident
``tok_buf`` keeps each run slot's last sampled token so the NEXT step's
decode rows can reference it (``MixedBatch.from_buf``) before the host
has ever seen the value — the mechanism behind the engine's one-step-
lookahead async submission (``EngineConfig.async_submission``).
``submit_batch`` dispatches without blocking and returns a
:class:`StepHandle`; ``fetch_sampled`` is the step's ONLY device→host
transfer (logged in ``d2h_fetches`` so benchmarks can assert the payload
stays sampled-ids-sized).

Pools:
  k_pool/v_pool:     (La, NB, bs, KV, hd)   — last block id is a write
                                              dump for padded slots
  live_ssm/conv:     (Ls, MR, ...)          — per running-slot SSM state
  snap_ssm/conv:     (Ls, NS, ...)          — block-boundary snapshots
                                              (cross-model state reuse)
  tok_buf:           (MR,) int32            — last sampled token per run
                                              slot (async decode feed)
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from functools import partial
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, SSM, ModelConfig
from repro.distributed import sharding as shd
from repro.distributed.sharding import StepShardings
from repro.kernels.ref import packed_cross_attention_ref, paged_attention_ref
from repro.models import attention as attn_dispatch
from repro.models import layers as Lyr
from repro.models import model as M
from repro.models import ssm as ssm_lib
from repro.models.model import Runtime
from repro.obs.tracer import Tracer

NEG_INF = -1e30


def next_pow2(n: int, lo: int = 1) -> int:
    v = lo
    while v < n:
        v *= 2
    return v


# bounded device→host fetch log (``ModelRunner.d2h_fetches``): trim the
# OLDEST half in bulk at the threshold so a long-lived engine never
# accumulates one entry per step forever
D2H_LOG_MAX = 4096
D2H_LOG_KEEP = 2048


def log_d2h(log: List[Tuple[int, str, str]], elems: int, dtype: str,
            tag: str, tracer: Optional[Tracer] = None) -> None:
    """Record one blocking device→host transfer as ``(elems, dtype, tag)``.

    Every host sync on the serving path must route through this logger —
    the hot-path lint (``repro.analysis.hotpath_lint``) rejects any
    ``# hotpath: sync-ok`` site whose function doesn't.  Tags:

      "step"  — the per-step sampled-ids fetch (benchmarks/tests assert
                the ids-only payload over exactly these entries)
      "xkv"   — enc-dec encoder-KV restack on a batch-membership miss
      "admit" — admission-time prompt-embedding materialization

    Overflow trims in bulk, keeping the most recent ``D2H_LOG_KEEP``
    entries in order (unit-tested in ``tests/test_analysis.py``).

    ``tracer`` (the runner's, when tracing is on) mirrors the transfer
    into the unified trace: a "d2h" event on the retire track plus
    per-tag element/transfer counters — the log and the trace stay one
    source of truth for the ids-only-D2H invariant.
    """
    if len(log) >= D2H_LOG_MAX:
        del log[:len(log) - D2H_LOG_KEEP]
    log.append((elems, dtype, tag))
    if tracer is not None and tracer.enabled:
        tracer.event("retire", "d2h", None,
                     {"elems": elems, "dtype": dtype, "tag": tag})
        tracer.count(f"d2h_{tag}_transfers_total")
        tracer.count(f"d2h_{tag}_elems_total", elems)


@dataclass(frozen=True)
class RunnerConfig:
    block_size: int = 16
    num_blocks: int = 512           # incl. 1 reserved dump block
    max_running: int = 9            # incl. 1 reserved dump slot
    num_state_slots: int = 65       # incl. 1 reserved dump slot
    chunk_tokens: int = 64          # max prefill chunk (multiple of bs)
    mixed_attn_impl: str = "ref"    # "ref" | "pallas" | "pallas_interpret"
    mixed_ssd_impl: str = "ref"     # "ref" | "pallas" | "pallas_interpret"
    # grouped-LoRA delta for the mixed step: "ref" (ragged jnp over the
    # step's active slots) | "pallas"/"pallas_interpret" (SGMV kernel) |
    # "dense" (the pre-pool full stacked scan; equivalence oracle)
    mixed_lora_impl: str = "ref"
    # shard the packed token axis of the mixed step over the mesh "data"
    # axis (per-token metadata + input embeds split; per-request arrays
    # and sampled ids replicated).  No-op without a mesh or with a
    # size-1 data axis; False keeps the replicate-everything TP layout.
    data_shard_tokens: bool = True


@dataclass(frozen=True)
class RunnerSpec:
    """Hashable static context for the jitted step functions."""
    cfg: ModelConfig
    block_size: int
    num_blocks: int
    window: int
    kinds: Tuple[str, ...]
    rt: Runtime = Runtime()
    attn_impl: str = "ref"
    ssd_impl: str = "ref"
    lora_impl: str = "ref"
    # TP-sharded execution over EngineConfig.mesh: pins the output
    # layouts of the mixed step (None = the single-device default path,
    # traced exactly as before)
    shard: Optional[StepShardings] = None


@dataclass
class MixedBatch:
    """One engine step's ragged token batch: all scheduled decode tokens
    plus all scheduled prefill chunks, packed along a single token axis
    with per-token metadata rows (vLLM v1-style single mixed batch).

    Per-token arrays (T,):
      tok_ids     — token id (embedded in-step; ignored where use_embeds)
      from_buf    — row's token id is NOT host-known: the step reads it
                    from the device-resident ``tok_buf`` at the row's run
                    slot instead (the previous step's sampled token —
                    async one-step-lookahead decode rows)
      use_embeds  — row comes from ``embeds`` instead (prefill rows,
                    incl. multimodal prefix embeds)
      positions   — absolute position in the request
      adapter_idx — activation-aware adapter index (0 = base)
      req_rows    — token → request row in the per-request arrays
      row_cols    — token's offset within its request's packed segment
                    (0 ⇒ segment start; SSM state/conv gather point)
      write_bids/write_offs — physical (block, offset) this token's K/V
                    is written to

    Per-request:
      block_tables — physical block ids (ragged list-of-lists)
      out_rows     — token index whose hidden state yields the request's
                    logits (chunk tail for prefill, the token itself for
                    decode); doubles as the segment-final index for the
                    SSM live-state scatter-back
      run_slots    — live-state slot per request (SSM/hybrid archs)
      xkv_list     — per-request projected encoder K/V (enc-dec archs)

    snap_rows — packed indices of prefill block-boundary tokens whose
    post-token SSM state is emitted for the prefix cache.
    """
    tok_ids: np.ndarray
    embeds: np.ndarray                       # (T, d)
    use_embeds: np.ndarray
    positions: np.ndarray
    adapter_idx: np.ndarray
    req_rows: np.ndarray
    row_cols: np.ndarray
    write_bids: np.ndarray
    write_offs: np.ndarray
    block_tables: List[List[int]]
    out_rows: np.ndarray
    run_slots: np.ndarray
    snap_rows: np.ndarray
    xkv_list: Optional[List[Tuple]] = None
    # ascending adapter-slot ids this step's tokens reference (grouped-
    # LoRA active set); padded with 0 (zero adapter) to a pow2 bucket
    active_slots: Optional[np.ndarray] = None
    # (T,) bool: token id comes from the device tok_buf, not tok_ids
    # (None -> all host-known, the sync-oracle assembly)
    from_buf: Optional[np.ndarray] = None


@dataclass
class StepHandle:
    """An in-flight mixed step: device futures only, nothing synced.

    ``sampled`` is the step's (Rb,) int32 on-device sampled-token array
    (argmax row per request, bucket-padded); ``boundary`` the SSM
    block-boundary state pair (or ``None``); ``n_requests`` the real row
    count.  ``ModelRunner.fetch_sampled`` performs the one blocking
    device→host transfer that retires the handle."""
    sampled: jax.Array
    boundary: Optional[Tuple]
    n_requests: int


def _chunk_attention(q, past_k, past_v, past_len, new_k, new_v,
                     start_pos, window: int):
    """Prefill-chunk attention over [cached past || current chunk].

    q/new_k/new_v: (1, C, H|KV, hd); past_k/past_v: (1, Sp, KV, hd);
    past entries valid where index < past_len.  Absolute positions:
    past j -> j, chunk i -> start_pos + i.
    """
    B, C, H, hd = q.shape
    KV = new_k.shape[2]
    G = H // KV
    Sp = past_k.shape[1]
    scale = 1.0 / (hd ** 0.5)
    qr = q.reshape(B, C, KV, G, hd)

    k_all = jnp.concatenate([past_k, new_k], axis=1)     # (1, Sp+C, KV, hd)
    v_all = jnp.concatenate([past_v, new_v], axis=1)
    s = jnp.einsum("bckgd,bskd->bkgcs", qr, k_all,
                   preferred_element_type=jnp.float32) * scale
    qpos = start_pos + jnp.arange(C, dtype=jnp.int32)    # (C,)
    kpos = jnp.concatenate([jnp.arange(Sp, dtype=jnp.int32),
                            start_pos + jnp.arange(C, dtype=jnp.int32)])
    valid = jnp.concatenate([jnp.arange(Sp) < past_len,
                             jnp.ones((C,), bool)])
    mask = valid[None, :] & (kpos[None, :] <= qpos[:, None])
    if window > 0:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgcs,bskd->bckgd", p, v_all.astype(jnp.float32))
    return o.reshape(B, C, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# jitted step functions (module level, static spec)
#
# The device pools (K/V, SSM live state, tok_buf) are DONATED to every
# step: each is consumed and returned updated, so without donation XLA
# would hold both generations live across the call — double the pool HBM.
# ``repro.analysis.step_audit`` statically verifies the aliasing survived
# compilation (input_output_alias) on every config × mesh; the HBM delta
# shows up in ``benchmarks/report.py``'s audit table.
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnums=0, donate_argnums=(3, 4, 5, 6))
def _prefill_impl(spec: RunnerSpec, params, adapter_layers, k_pool, v_pool,
                  live_ssm, live_conv, x_chunk, valid_len, start_pos,
                  block_table, adapter_idx, run_slot, xkv):
    cfg, rt = spec.cfg, spec.rt
    bs = spec.block_size
    Cb = x_chunk.shape[1]
    dump = spec.num_blocks - 1
    x = x_chunk
    positions = (start_pos + jnp.arange(Cb, dtype=jnp.int32))[None]  # (1,Cb)
    gpos = positions[0]
    i_valid = jnp.arange(Cb) < valid_len
    nbb = block_table.shape[0]
    bids = jnp.where(i_valid,
                     block_table[jnp.clip(gpos // bs, 0, nbb - 1)], dump)
    offs = gpos % bs
    boundary_ssm, boundary_conv = [], []
    ai = si = 0
    layers_params = [lp for _, lp in M.iter_layers(params, cfg)]
    for li, kind in enumerate(spec.kinds):
        lp = layers_params[li]
        al = adapter_layers[li]
        if kind == SSM:
            h = Lyr.rmsnorm(x, lp["ln"], cfg.norm_eps)
            st = live_ssm[si, run_slot][None]
            cv = live_conv[si, run_slot][None]
            y, st2, cv2, (bs_ssm, bs_conv) = ssm_lib.ssd_forward(
                lp["ssm"], cfg, h, ssm_state=st, conv_state=cv,
                alora=al, adapter_idx=adapter_idx,
                valid_len=valid_len, return_boundary_states=True)
            live_ssm = live_ssm.at[si, run_slot].set(st2[0])
            live_conv = live_conv.at[si, run_slot].set(cv2[0])
            boundary_ssm.append(bs_ssm[:, 0])          # (nc, nh, N, P)
            boundary_conv.append(bs_conv[:, 0])
            x = x + y
            si += 1
        else:
            h = Lyr.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = Lyr.qkv_project(lp["attn"], cfg, h, al, adapter_idx)
            q = Lyr.apply_rope(q, positions, cfg.rope_theta)
            k = Lyr.apply_rope(k, positions, cfg.rope_theta)
            past_k = k_pool[ai][block_table].reshape(
                1, -1, cfg.num_kv_heads, cfg.head_dim)
            past_v = v_pool[ai][block_table].reshape(
                1, -1, cfg.num_kv_heads, cfg.head_dim)
            o = _chunk_attention(q, past_k, past_v, start_pos,
                                 k, v, start_pos, spec.window)
            x = x + Lyr.out_project(lp["attn"], cfg, o)
            k_pool = k_pool.at[ai, bids, offs].set(k[0])
            v_pool = v_pool.at[ai, bids, offs].set(v[0])
            if cfg.is_encoder_decoder:
                x = M.cross_attn_sublayer(
                    lp, cfg, x, xkv[0][ai][None], xkv[1][ai][None])
            x, _ = M.mlp_sublayer(lp, cfg, rt, x)
            ai += 1
    x = Lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    last_h = jax.lax.dynamic_index_in_dim(
        x[0], jnp.maximum(valid_len - 1, 0), axis=0, keepdims=False)
    logits = M.logits_for(params, cfg, last_h)
    b_ssm = jnp.stack(boundary_ssm) if boundary_ssm else 0
    b_conv = jnp.stack(boundary_conv) if boundary_conv else 0
    return (k_pool, v_pool, live_ssm, live_conv, b_ssm, b_conv, logits)


@partial(jax.jit, static_argnums=0, donate_argnums=(3, 4, 5, 6))
def _decode_impl(spec: RunnerSpec, params, adapter_layers, k_pool, v_pool,
                 live_ssm, live_conv, tokens, positions, block_tables,
                 lengths, adapter_idx, run_slots, write_bids, write_offs,
                 xkv):
    cfg, rt = spec.cfg, spec.rt
    x = params["embed"]["tok"][tokens][:, None, :]       # (Bb, 1, d)
    pos2 = positions[:, None]                            # (Bb, 1)
    aidx2 = adapter_idx[:, None]
    ai = si = 0
    layers_params = [lp for _, lp in M.iter_layers(params, cfg)]
    for li, kind in enumerate(spec.kinds):
        lp = layers_params[li]
        al = adapter_layers[li]
        if kind == SSM:
            h = Lyr.rmsnorm(x, lp["ln"], cfg.norm_eps)
            st = live_ssm[si, run_slots]
            cv = live_conv[si, run_slots]
            y, st2, cv2 = ssm_lib.ssd_decode_step(
                lp["ssm"], cfg, h, st, cv, alora=al, adapter_idx=aidx2)
            live_ssm = live_ssm.at[si, run_slots].set(st2)
            live_conv = live_conv.at[si, run_slots].set(cv2)
            x = x + y
            si += 1
        else:
            h = Lyr.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = Lyr.qkv_project(lp["attn"], cfg, h, al, aidx2)
            q = Lyr.apply_rope(q, pos2, cfg.rope_theta)
            k = Lyr.apply_rope(k, pos2, cfg.rope_theta)
            k_pool = k_pool.at[ai, write_bids, write_offs].set(k[:, 0])
            v_pool = v_pool.at[ai, write_bids, write_offs].set(v[:, 0])
            o = paged_attention_ref(q[:, 0], k_pool[ai], v_pool[ai],
                                    block_tables, lengths,
                                    window=spec.window)
            x = x + Lyr.out_project(lp["attn"], cfg, o[:, None])
            if cfg.is_encoder_decoder:
                x = M.cross_attn_sublayer(lp, cfg, x,
                                          xkv[0][:, ai], xkv[1][:, ai])
            x, _ = M.mlp_sublayer(lp, cfg, rt, x)
            ai += 1
    x = Lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = M.logits_for(params, cfg, x[:, 0])
    return k_pool, v_pool, live_ssm, live_conv, logits


@partial(jax.jit, static_argnums=0, donate_argnums=(3, 4, 5, 6, 7))
def _mixed_impl(spec: RunnerSpec, params, adapter_layers, k_pool, v_pool,
                live_ssm, live_conv, tok_buf, tok_ids, embeds, use_embeds,
                from_buf, positions, q_lens, adapter_idx, active_slots,
                block_tables, req_rows, row_cols, write_bids, write_offs,
                out_rows, run_slots, tok_slots, snap_rows, xkv):
    """One jitted step over the whole mixed batch — every architecture
    family shares this single device call:

    * attention: all K/V rows are written to the paged pool first, then
      every token attends over its request's blocks through the ragged
      paged-attention path — intra-chunk causality is just the q_lens
      mask, so prefill chunks and decode tokens share one code path;
    * SSM (pure and hybrid): a ragged SSD scan over the packed token
      axis — each request's live recurrent/conv state is gathered at its
      segment start (``row_cols == 0``), scanned through its tokens, and
      scattered back at its final token, with block-boundary states
      emitted at ``snap_rows`` for the prefix cache;
    * encoder-decoder: every token cross-attends over its OWN request's
      projected encoder K/V, gathered per token by ``req_rows``.

    Sampling is part of the step: the per-request logits rows reduce to
    an argmax ON DEVICE, the sampled ids land in ``tok_buf`` at each
    request's run slot (next step's decode rows read them back through
    ``from_buf`` without a host round-trip), and only the (Rb,) int32
    ``sampled`` array is ever fetched by the host.
    """
    cfg, rt = spec.cfg, spec.rt
    # decode rows submitted before their token reached the host read the
    # previous step's sampled token straight from the device buffer
    tok_ids = jnp.where(from_buf, tok_buf[tok_slots], tok_ids)
    tok_emb = params["embed"]["tok"][tok_ids]
    x = jnp.where(use_embeds[:, None], embeds.astype(tok_emb.dtype),
                  tok_emb)[None]                             # (1, Tb, d)
    Tb = tok_ids.shape[0]
    pos2 = positions[None]                                   # (1, Tb)
    aidx2 = adapter_idx[None]
    ai = si = 0
    boundary_ssm, boundary_conv = [], []
    layers_params = [lp for _, lp in M.iter_layers(params, cfg)]
    for li, kind in enumerate(spec.kinds):
        lp = layers_params[li]
        al = adapter_layers[li]
        if kind == SSM:
            h = Lyr.rmsnorm(x, lp["ln"], cfg.norm_eps)
            y, l_ssm, l_conv, sb_s, sb_c = ssm_lib.ssd_ragged_forward(
                lp["ssm"], cfg, h[0], live_ssm=live_ssm[si],
                live_conv=live_conv[si], tok_slots=tok_slots,
                row_cols=row_cols, seg_ids=req_rows,
                snap_rows=snap_rows, last_rows=out_rows,
                row_slots=run_slots, alora=al, adapter_idx=adapter_idx,
                impl=spec.ssd_impl, lora_impl=spec.lora_impl,
                active_slots=active_slots)
            live_ssm = live_ssm.at[si].set(l_ssm)
            live_conv = live_conv.at[si].set(l_conv)
            boundary_ssm.append(sb_s)
            boundary_conv.append(sb_c)
            x = x + y[None]
            si += 1
        else:
            h = Lyr.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = Lyr.qkv_project(lp["attn"], cfg, h, al, aidx2,
                                      lora_impl=spec.lora_impl,
                                      active_slots=active_slots)
            q = Lyr.apply_rope(q, pos2, cfg.rope_theta)
            k = Lyr.apply_rope(k, pos2, cfg.rope_theta)
            k_pool = k_pool.at[ai, write_bids, write_offs].set(k[0])
            v_pool = v_pool.at[ai, write_bids, write_offs].set(v[0])
            o = attn_dispatch.ragged_paged_attention(
                q[0], k_pool[ai], v_pool[ai], block_tables, req_rows,
                q_lens, window=spec.window, impl=spec.attn_impl)
            if spec.shard is not None:
                o = spec.shard.constrain(o, spec.shard.attn_out)
            x = x + Lyr.out_project(lp["attn"], cfg, o[None])
            if cfg.is_encoder_decoder:
                hx = Lyr.rmsnorm(x, lp["xln"], cfg.norm_eps)
                qx = (hx[0] @ lp["xattn"]["wq"]).reshape(
                    Tb, cfg.num_heads, cfg.head_dim)
                ox = packed_cross_attention_ref(
                    qx, xkv[0][ai][req_rows], xkv[1][ai][req_rows])
                x = x + Lyr.out_project(lp["xattn"], cfg, ox[None])
            x, _ = M.mlp_sublayer(lp, cfg, rt, x)
            ai += 1
    x = Lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = M.logits_for(params, cfg, x[0][out_rows])       # (Rb, V)
    # on-device sampling: argmax per request row; the sampled ids are the
    # step's only host-visible output AND feed the next step's decode
    # rows through the per-run-slot token buffer.  Padded request rows
    # all target the reserved dump slot.
    sampled = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tok_buf = tok_buf.at[run_slots].set(sampled)
    b_ssm = jnp.stack(boundary_ssm) if boundary_ssm else 0
    b_conv = jnp.stack(boundary_conv) if boundary_conv else 0
    if spec.shard is not None:
        # pin the output layouts so the pools round-trip through the step
        # with the exact sharding they were created with (no resharding
        # between steps, no post-warmup recompiles); sampled ids and the
        # token buffer gather replicated — sampling is the step's single
        # cross-shard reduction beyond the row-parallel psums
        sh = spec.shard
        k_pool = sh.constrain(k_pool, sh.kv_pool)
        v_pool = sh.constrain(v_pool, sh.kv_pool)
        live_ssm = sh.constrain(live_ssm, sh.ssm_pool)
        live_conv = sh.constrain(live_conv, sh.conv_pool)
        if boundary_ssm:
            b_ssm = sh.constrain(b_ssm, sh.ssm_pool)
            b_conv = sh.constrain(b_conv, sh.conv_pool)
        tok_buf = sh.constrain(tok_buf, sh.tok_buf)
        sampled = sh.constrain(sampled, sh.tok_buf)
    return (k_pool, v_pool, live_ssm, live_conv, tok_buf, b_ssm, b_conv,
            sampled)


@partial(jax.jit, static_argnums=0)
def _encode_impl(spec: RunnerSpec, params, frames):
    cfg = spec.cfg
    enc_out = M._run_encoder(params["encoder"], cfg, spec.rt, frames[None])
    xks, xvs = [], []
    layers_params = [lp for _, lp in M.iter_layers(params, cfg)]
    for li, kind in enumerate(spec.kinds):
        if kind != ATTN:
            continue
        lp = layers_params[li]
        xk, xv = M.encoder_kv(lp, cfg, enc_out)
        xks.append(xk[0])
        xvs.append(xv[0])
    return jnp.stack(xks), jnp.stack(xvs)                # (La, Se, KV, hd)


def jit_cache_size() -> int:
    """Total cached traces across this module's jitted step functions —
    the recompile counter the churn/sharding zero-post-warmup-recompile
    invariants are asserted on (benchmarks + tests/test_sharded_step.py).
    Lives here so adding a jitted impl can't silently escape counting.
    """
    return sum(f._cache_size() for f in (
        _mixed_impl, _prefill_impl, _decode_impl, _encode_impl))


# ---------------------------------------------------------------------------
class HostBufferPool:
    """Persistent capacity-doubling numpy buffers for per-step batch
    assembly (ROADMAP "pinned buffer" item).

    The mixed path used to reallocate every host-side assembly array
    (tok_ids, embeds, write_bids, ...) each step; this pool hands out
    slices of long-lived buffers instead, growing a buffer by doubling
    only when a step outgrows it.  ``take`` re-fills the slice (memset,
    no allocation) so callers see the same zero/dump-initialized contents
    the old np.zeros/np.full calls produced.

    Set ``REPRO_HOST_BUF_REUSE=0`` to allocate fresh arrays per call —
    the pre-pool behavior, kept for A/B assembly-time measurements
    (``benchmarks/bench_mixed_batch.py`` reports assembly_us_per_step).

    The pool is DOUBLE-BUFFERED across submissions (``flip``): jax's CPU
    backend zero-copies suitably-aligned numpy arrays into device
    buffers, so a staging buffer may be aliased by a dispatched-but-
    unfinished step — refilling it for the next step would corrupt the
    in-flight computation.  With one-step-lookahead submission at most
    ONE step is ever in flight, so alternating between two buffer sets
    (one flip per submitted step) guarantees a submission never rewrites
    memory the previous step still reads.  A deeper pipeline would need
    ``depth + 1`` generations.
    """

    def __init__(self):
        self._bufs: dict = {}
        self._gen = 0
        self._reuse = os.environ.get("REPRO_HOST_BUF_REUSE", "1") != "0"

    def flip(self) -> None:
        """Advance to the other buffer generation — call once per
        submitted step, BEFORE taking that step's staging buffers."""
        self._gen ^= 1

    def take(self, name: str, n: int, dtype, *, trailing: Tuple[int, ...] = (),
             fill=0) -> np.ndarray:
        if not self._reuse:
            return np.full((n,) + trailing, fill, dtype)
        # trailing dims are part of the key: buffers whose width
        # oscillates between steps (block tables by nbb, xk/xv by Rb —
        # already pow2-bucketed) each keep their own pooled buffer
        # instead of thrashing a single slot
        key = (name, trailing, np.dtype(dtype).str, self._gen)
        buf = self._bufs.get(key)
        if buf is None or buf.shape[0] < n:
            cap = next_pow2(max(n, 1))
            buf = np.empty((cap,) + trailing, dtype)
            self._bufs[key] = buf
        view = buf[:n]
        view[...] = fill
        return view


class ModelRunner:
    def __init__(self, cfg: ModelConfig, params, rcfg: RunnerConfig,
                 adapter_layers: Optional[List[Any]] = None,
                 rt: Runtime = Runtime(),
                 mesh: Optional[jax.sharding.Mesh] = None,
                 tracer: Optional[Tracer] = None):
        """``adapter_layers``: per-layer stacked adapter pytrees (leaves
        with a leading slot axis) — normally the AdapterPool's live
        ``layers`` list, whose entries the pool replaces in place as
        adapters move through slots.  The runner keeps the list object
        and re-reads it every step.

        ``mesh``: TP-shard the mixed step over this mesh (see the
        "Sharded serving" section of ``distributed.sharding``): params go
        tensor-parallel, the paged K/V pool splits on its KV-head dim,
        SSM pools on their head/channel dims, and per-step metadata is
        replicated.  ``None`` keeps the single-device default path
        byte-identical to before."""
        if cfg.ssm is not None and cfg.ssm.chunk_size != rcfg.block_size:
            # align SSD chunk boundaries with KV-block boundaries so state
            # snapshots land exactly on block-hash boundaries
            import dataclasses as _dc
            cfg = cfg.replace(ssm=_dc.replace(cfg.ssm,
                                              chunk_size=rcfg.block_size))
        self.cfg = cfg
        self.rcfg = rcfg
        self.rt = rt
        self.mesh = mesh
        self._shard: Optional[StepShardings] = None
        self._meta_sharding = None
        self._rep_sharding = None
        # token-bucket floor: pow2 buckets double from here so the packed
        # token axis always divides the data-axis shard count
        self._tok_bucket_lo = 1
        if mesh is not None:
            allowed = (("attn", rcfg.mixed_attn_impl, ("ref",)),
                       ("ssd", rcfg.mixed_ssd_impl, ("ref",)),
                       ("lora", rcfg.mixed_lora_impl, ("ref", "dense")))
            for kind_, impl, ok in allowed:
                if impl not in ok:
                    raise ValueError(
                        f"mixed_{kind_}_impl={impl!r} is not usable under "
                        f"a mesh (Pallas kernels are single-device); the "
                        f"TP-sharded step requires one of {ok}, which "
                        "GSPMD partitions over the mesh")
            pshape = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
            pspecs = shd.param_specs_tree(cfg, pshape, mesh=mesh)
            params = jax.device_put(params, shd.to_named(pspecs, mesh))
            data_axis = "data" if rcfg.data_shard_tokens \
                and "data" in mesh.axis_names else None
            self._shard = shd.mixed_step_shardings(cfg, mesh,
                                                   data_axis=data_axis)
            # data-sharded token axis: pad every token bucket to a
            # multiple of the data-axis size so P(data) always divides
            tok_ax = next((a for a in self._shard.tok_meta
                           if a is not None), None)
            if tok_ax is not None:
                self._tok_bucket_lo = int(mesh.shape[tok_ax])
            sh = self._shard
            tm, te = sh.named(sh.tok_meta), sh.named(sh.tok_embeds)
            rep = sh.named(sh.replicated)
            # per-leaf layout of the _assemble_mixed meta tuple:
            # (tok, emb, use, fb, pos, qln, ad, act, bt, rows, cols, wb,
            #  wo, out_rows, run_slots, tok_slots, snap) — token-axis
            # leaves split over data, request/slot-axis leaves replicated
            self._meta_sharding = (tm, te, tm, tm, tm, tm, tm, rep, rep,
                                   tm, tm, tm, tm, rep, rep, tm, rep)
            self._rep_sharding = rep
        self.params = params
        self.kinds = [k for k, _ in M.iter_layers(params, cfg)]
        self.attn_ids = [i for i, k in enumerate(self.kinds) if k == ATTN]
        self.ssm_ids = [i for i, k in enumerate(self.kinds) if k == SSM]
        self.La, self.Ls = len(self.attn_ids), len(self.ssm_ids)
        self.window = M.effective_window(cfg, rt)
        self._spec = RunnerSpec(cfg=cfg, block_size=rcfg.block_size,
                                num_blocks=rcfg.num_blocks,
                                window=self.window,
                                kinds=tuple(self.kinds), rt=rt,
                                attn_impl=rcfg.mixed_attn_impl,
                                ssd_impl=rcfg.mixed_ssd_impl,
                                lora_impl=rcfg.mixed_lora_impl,
                                shard=self._shard)
        self.host_bufs = HostBufferPool()
        self._xkv_stack = (None, None)   # (membership key, stacked xk/xv)
        # device-call accounting (what benchmarks/bench_mixed_batch.py
        # reports): one entry per jitted step dispatched
        self.call_counts = {"prefill_chunk": 0, "decode_batch": 0,
                            "mixed_step": 0, "encode": 0}
        # runner-side host prep time (bucket padding + xkv stacking);
        # the engine adds its packing time — the benchmark reports the sum
        self.t_assembly = 0.0
        # (elements, dtype, tag) of every blocking device→host fetch on
        # the serving path — benchmarks assert the per-step ("step" tag)
        # D2H payload is the sampled int32 ids, never the (R, vocab)
        # logits; see ``log_d2h`` for the tag vocabulary
        self.d2h_fetches: List[Tuple[int, str, str]] = []
        # trace recorder shared with the owning engine (a disabled one
        # when constructed standalone) — log_d2h mirrors into it
        self.tracer = tracer if tracer is not None \
            else Tracer(enabled=False)

        # per-layer adapter stacks aligned with layer order (the shared
        # AdapterPool list, or inert Nones for adapter-free engines)
        if adapter_layers is not None:
            assert len(adapter_layers) == len(self.kinds)
            self.adapter_layers = adapter_layers
        else:
            self.adapter_layers = [None] * len(self.kinds)

        dtype = Lyr.dtype_of(cfg)
        bs, NB = rcfg.block_size, rcfg.num_blocks
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        self.k_pool = self._pool(
            jnp.zeros((max(self.La, 1), NB, bs, KV, hd), dtype),
            None if self._shard is None else self._shard.kv_pool)
        self.v_pool = self._pool(
            jnp.zeros_like(self.k_pool),
            None if self._shard is None else self._shard.kv_pool)
        if self.Ls:
            s = cfg.ssm
            d_inner, nh, ch = ssm_lib.ssm_dims(cfg)
            MR, NS = rcfg.max_running, rcfg.num_state_slots
            sh = self._shard
            ssm_spec = None if sh is None else sh.ssm_pool
            conv_spec = None if sh is None else sh.conv_pool
            self.live_ssm = self._pool(
                jnp.zeros((self.Ls, MR, nh, s.state_dim, s.head_dim),
                          jnp.float32), ssm_spec)
            self.live_conv = self._pool(
                jnp.zeros((self.Ls, MR, s.conv_width - 1, ch), dtype),
                conv_spec)
            self.snap_ssm = self._pool(
                jnp.zeros((self.Ls, NS, nh, s.state_dim, s.head_dim),
                          jnp.float32), ssm_spec)
            self.snap_conv = self._pool(
                jnp.zeros((self.Ls, NS, s.conv_width - 1, ch), dtype),
                conv_spec)
        else:
            self.live_ssm = self.live_conv = None
            self.snap_ssm = self.snap_conv = None
        # last sampled token per run slot (every arch family): lets the
        # next step's decode rows reference a token the host has not yet
        # fetched (async one-step lookahead)
        self.tok_buf = self._pool(
            jnp.zeros((rcfg.max_running,), jnp.int32),
            None if self._shard is None else self._shard.tok_buf)

    # ------------------------------------------------------------------
    # sharded-execution helpers
    # ------------------------------------------------------------------
    def _pool(self, a: jax.Array, spec) -> jax.Array:
        """Place a device pool in its step layout (no-op when unsharded)."""
        if spec is None or self._shard is None:
            return a
        return jax.device_put(a, self._shard.named(spec))

    def _dev(self, a):
        """Stage host data on device, replicated over the mesh in sharded
        mode, the plain default placement otherwise.  Accepts a pytree."""
        if self._rep_sharding is not None:
            return jax.device_put(a, self._rep_sharding)
        return jax.tree.map(jnp.asarray, a)

    def _dev_meta(self, meta: Tuple):
        """Stage the mixed step's 17-leaf metadata tuple on device in its
        step layout — token-axis leaves split over the data axis when
        token sharding is on (replicated otherwise), per-request/slot
        leaves always replicated.  One batched transfer for the whole
        tuple rather than a dispatch per array."""
        if self._meta_sharding is not None:
            return jax.device_put(meta, self._meta_sharding)
        return jax.tree.map(jnp.asarray, meta)

    # ------------------------------------------------------------------
    # embeddings
    # ------------------------------------------------------------------
    def embed_tokens(self, tokens: np.ndarray) -> jax.Array:
        return self.params["embed"]["tok"][jnp.asarray(tokens)]

    def build_input_embeds(self, prompt: List[int],
                           prefix_embeds: Optional[np.ndarray]) -> np.ndarray:
        """Materialize a request's prompt embeddings HOST-SIDE (numpy) at
        admission, so every later mixed-batch assembly packs its rows
        with plain slice copies and zero device round-trips.  The one
        device→host sync this costs happens once per admitted request,
        never per step, and is logged under the "admit" tag."""
        emb = np.asarray(  # hotpath: sync-ok (once per admission)
            self.embed_tokens(np.array(prompt, np.int32)))
        log_d2h(self.d2h_fetches, int(emb.size), str(emb.dtype), "admit",
                self.tracer)
        if prefix_embeds is not None:
            pe = prefix_embeds.astype(emb.dtype, copy=False)
            # hashing pseudo-tokens already cover the patch prefix; the
            # embeds replace the leading len(pe) rows
            emb = np.concatenate([pe, emb[len(pe):]], axis=0) \
                if len(prompt) >= pe.shape[0] else pe
        return emb

    # ------------------------------------------------------------------
    # encoder (whisper)
    # ------------------------------------------------------------------
    def encode(self, frames: np.ndarray):
        self.call_counts["encode"] += 1
        return _encode_impl(self._spec, self.params, jnp.asarray(frames))

    @property
    def num_device_calls(self) -> int:
        return sum(self.call_counts.values())

    # ------------------------------------------------------------------
    # unified mixed-batch step (decode tokens + prefill chunks, one call)
    # ------------------------------------------------------------------
    def _assemble_mixed(self, mb: MixedBatch) -> Tuple:
        """Host-side half of :meth:`submit_batch`: bucket the ragged
        batch into the pooled pow2-padded staging buffers and stage the
        metadata on device.  Returns the EXACT positional argument tuple
        ``_mixed_impl`` is dispatched with — :meth:`lower_mixed` lowers
        the same tuple, so the static auditor analyzes precisely the
        compiled artifact production dispatches."""
        t_host = time.perf_counter()
        # new staging generation: never rewrite buffers the (at most
        # one) still-executing previous step may alias zero-copy
        self.host_bufs.flip()
        rc = self.rcfg
        T = len(mb.tok_ids)
        R = len(mb.block_tables)
        C = len(mb.snap_rows)
        dump_block = rc.num_blocks - 1
        dump_slot = rc.max_running - 1
        # bucketed shapes (powers of two) bound the jit trace count; the
        # token bucket doubles from the data-shard floor so P(data)
        # always divides the packed axis
        Tb = next_pow2(max(T, 1), lo=self._tok_bucket_lo)
        Rb = next_pow2(max(R, 1))
        Cb = next_pow2(max(C, 1))
        nbb = next_pow2(max(max((len(t) for t in mb.block_tables),
                                default=1), 1))

        dtype = Lyr.dtype_of(self.cfg)
        take = self.host_bufs.take
        tok = take("tok", Tb, np.int32)
        tok[:T] = mb.tok_ids
        emb = take("emb", Tb, np.float32, trailing=(self.cfg.d_model,))
        emb[:T] = mb.embeds
        use = take("use", Tb, bool)
        use[:T] = mb.use_embeds
        pos = take("pos", Tb, np.int32)
        pos[:T] = mb.positions
        # causal length per token; 0 fully masks padded rows
        qln = take("qln", Tb, np.int32)
        qln[:T] = mb.positions + 1
        ad = take("ad", Tb, np.int32)
        ad[:T] = mb.adapter_idx
        rows = take("rows", Tb, np.int32, fill=Rb - 1)
        rows[:T] = mb.req_rows
        cols = take("cols", Tb, np.int32)
        cols[:T] = mb.row_cols
        wb = take("wb", Tb, np.int32, fill=dump_block)
        wb[:T] = mb.write_bids
        wo = take("wo", Tb, np.int32)
        wo[:T] = mb.write_offs
        bt = take("bt", Rb, np.int32, trailing=(nbb,), fill=dump_block)
        for i, t in enumerate(mb.block_tables):
            bt[i, :len(t)] = t
        out_rows = take("out_rows", Rb, np.int32)
        out_rows[:R] = mb.out_rows
        run_slots = take("run_slots", Rb, np.int32, fill=dump_slot)
        run_slots[:R] = mb.run_slots
        # per-token run slot for the ragged SSD state/conv gathers
        tok_slots = take("tok_slots", Tb, np.int32, fill=dump_slot)
        tok_slots[:T] = run_slots[rows[:T]]
        fb = take("fb", Tb, bool)
        if mb.from_buf is not None:
            fb[:T] = mb.from_buf
        snap = take("snap", Cb, np.int32)
        snap[:C] = mb.snap_rows
        # active adapter slots, pow2-bucketed; padding entries are slot 0
        # (the zero adapter — an exact no-op term in the grouped delta)
        acts = mb.active_slots if mb.active_slots is not None \
            else np.zeros((0,), np.int32)
        Ab = next_pow2(max(len(acts), 1))
        act = take("act", Ab, np.int32)
        act[:len(acts)] = acts
        xkv = self._stack_xkv(mb.xkv_list, Rb, dtype) \
            if mb.xkv_list is not None else None
        self.t_assembly += time.perf_counter() - t_host

        meta = self._dev_meta((tok, emb, use, fb, pos, qln, ad, act, bt,
                               rows, cols, wb, wo, out_rows, run_slots,
                               tok_slots, snap))
        return (self._spec, self.params, self.adapter_layers, self.k_pool,
                self.v_pool, self.live_ssm, self.live_conv, self.tok_buf,
                *meta, xkv)

    def submit_batch(self, mb: MixedBatch) -> StepHandle:
        """Dispatch one mixed ragged batch as a single jitted device call
        WITHOUT blocking on its result.

        Returns a :class:`StepHandle` whose ``sampled`` array holds the
        on-device argmax-sampled token id per request row (taken at that
        request's last packed token) and whose ``boundary`` is ``None``
        for attention-only archs, else a ``(b_ssm (Ls, Cb, nh, N, P),
        b_conv (Ls, Cb, W-1, ch))`` pair of post-token SSM states at the
        batch's ``snap_rows`` (prefill block boundaries), in snap-row
        order, for prefix-cache state registration.  The caller retires
        the handle with :meth:`fetch_sampled` — in async mode only after
        the NEXT step has been submitted.

        The pools ride donated through the call (``_mixed_impl``'s
        ``donate_argnums``) and are immediately rebound to the step's
        outputs below — the pre-step arrays are dead the moment the step
        is dispatched, and XLA reuses their buffers for the outputs.
        """
        R = len(mb.block_tables)
        args = self._assemble_mixed(mb)
        self.call_counts["mixed_step"] += 1
        (self.k_pool, self.v_pool, live_ssm, live_conv, self.tok_buf,
         b_ssm, b_conv, sampled) = _mixed_impl(*args)
        boundary = None
        if self.Ls:
            self.live_ssm, self.live_conv = live_ssm, live_conv
            boundary = (b_ssm, b_conv)
        return StepHandle(sampled=sampled, boundary=boundary,
                          n_requests=R)

    def lower_mixed(self, mb: MixedBatch):
        """Lower (but do not execute) the mixed step EXACTLY as
        :meth:`submit_batch` would dispatch it — same jitted function,
        same static spec, same donation, same bucketed shapes — and
        return the :class:`jax.stages.Lowered`.  This is the entry point
        of the compiled-step auditor (``repro.analysis.step_audit``):
        auditing anything other than this tuple would verify a step
        production never runs."""
        return _mixed_impl.lower(*self._assemble_mixed(mb))

    def fetch_sampled(self, handle: StepHandle) -> np.ndarray:
        """Block until ``handle``'s step finished and return its sampled
        token ids, (R,) int32 — the mixed path's ONLY per-step
        device→host transfer (a few bytes per request, never the full
        logits).  Retire-phase: the blocking sync is allowed here."""
        log_d2h(self.d2h_fetches, int(handle.sampled.size),
                str(np.dtype(handle.sampled.dtype)), "step", self.tracer)
        return np.asarray(handle.sampled)[:handle.n_requests]

    def execute_batch(self, mb: MixedBatch):
        """Synchronous submit+fetch convenience wrapper: returns
        (sampled (R,) int32, boundary)."""
        handle = self.submit_batch(mb)
        return self.fetch_sampled(handle), handle.boundary

    def _stack_xkv(self, xkv_list, Rb: int, dtype):
        """Stack per-request encoder K/V into an (La, Rb, Se, KV, hd)
        pair (``xkv_list``: [(req_id, (xk, xv)), ...] in batch-row order).

        Cached by batch membership: a request's encoder K/V never changes
        during its lifetime, so steady-state decode restacks nothing.
        """
        key = (tuple((rid, id(k)) for rid, (k, _) in xkv_list), Rb)
        if self._xkv_stack[0] == key:
            return self._xkv_stack[1]
        Se = xkv_list[0][1][0].shape[1]
        KV, hd = self.cfg.num_kv_heads, self.cfg.head_dim
        # FRESH arrays on every membership miss, never pooled: the
        # stacked device arrays are cached across steps, so they can
        # outlive both HostBufferPool generations — a pooled buffer
        # could be rewritten while an in-flight step still (zero-copy)
        # reads the cached stack.  Misses are rare (membership changes),
        # steady-state decode hits the cache and allocates nothing.
        xk = np.zeros((self.La, Rb, Se, KV, hd), dtype)
        xv = np.zeros_like(xk)
        for i, (_, (k_, v_)) in enumerate(xkv_list):
            xk[:, i] = np.asarray(k_)  # hotpath: sync-ok (membership miss)
            xv[:, i] = np.asarray(v_)  # hotpath: sync-ok (membership miss)
        log_d2h(self.d2h_fetches, int(xk.size + xv.size), str(xk.dtype),
                "xkv", self.tracer)
        stacked = (self._dev(xk), self._dev(xv))
        self._xkv_stack = (key, stacked)
        return stacked

    # ------------------------------------------------------------------
    # prefill chunk
    # ------------------------------------------------------------------
    def prefill_chunk(self, *, input_embeds, lo: int, hi: int,
                      block_ids: List[int], adapter_idx_row: np.ndarray,
                      run_slot: int, xkv=None):
        """Execute prefill of tokens [lo, hi) of one request.

        Returns (logits at token hi-1 (V,), boundary states).
        The chunk is padded to a bucket; the block table to pow2.
        """
        rc = self.rcfg
        C = hi - lo
        Cb = next_pow2(C, lo=min(rc.block_size, rc.chunk_tokens))
        x = jnp.zeros((1, Cb, self.cfg.d_model), input_embeds.dtype)
        x = x.at[0, :C].set(input_embeds[lo:hi])
        nbb = next_pow2(max(len(block_ids), 1))
        bt = np.full((nbb,), rc.num_blocks - 1, np.int32)
        bt[:len(block_ids)] = block_ids
        aidx = np.zeros((1, Cb), np.int32)
        aidx[0, :C] = adapter_idx_row
        self.call_counts["prefill_chunk"] += 1
        (self.k_pool, self.v_pool, live_ssm, live_conv, b_ssm, b_conv,
         logits) = _prefill_impl(
            self._spec, self.params, self.adapter_layers, self.k_pool,
            self.v_pool, self.live_ssm, self.live_conv, x,
            jnp.asarray(C, jnp.int32), jnp.asarray(lo, jnp.int32),
            jnp.asarray(bt), jnp.asarray(aidx),
            jnp.asarray(run_slot, jnp.int32), xkv)
        if self.Ls:
            self.live_ssm, self.live_conv = live_ssm, live_conv
        return logits, (b_ssm, b_conv)

    # ------------------------------------------------------------------
    # decode batch
    # ------------------------------------------------------------------
    def decode_batch(self, *, tokens: np.ndarray, positions: np.ndarray,
                     block_tables: List[List[int]], lengths: np.ndarray,
                     adapter_idx: np.ndarray, run_slots: np.ndarray,
                     xkv_list=None):
        """One decode step for a batch of requests (host-padded).

        Returns logits (B, V) for the real rows.
        """
        rc = self.rcfg
        B = len(tokens)
        Bb = next_pow2(B)
        dump_block = rc.num_blocks - 1
        dump_slot = rc.max_running - 1
        nbb = next_pow2(max(max((len(t) for t in block_tables), default=1),
                            1))
        tok = np.zeros((Bb,), np.int32)
        tok[:B] = tokens
        pos = np.zeros((Bb,), np.int32)
        pos[:B] = positions
        bt = np.full((Bb, nbb), dump_block, np.int32)
        for i, t in enumerate(block_tables):
            bt[i, :len(t)] = t
        ln = np.zeros((Bb,), np.int32)
        ln[:B] = lengths
        ad = np.zeros((Bb,), np.int32)
        ad[:B] = adapter_idx
        rs = np.full((Bb,), dump_slot, np.int32)
        rs[:B] = run_slots
        wb = np.full((Bb,), dump_block, np.int32)
        wo = np.zeros((Bb,), np.int32)
        for i in range(B):
            p = positions[i]
            if block_tables[i]:                # attn-free archs: no KV
                wb[i] = block_tables[i][p // rc.block_size]
                wo[i] = p % rc.block_size
        xkv = None
        if xkv_list is not None:
            Se = xkv_list[0][0].shape[1]
            KV, hd = self.cfg.num_kv_heads, self.cfg.head_dim
            xk = jnp.zeros((Bb, self.La, Se, KV, hd), xkv_list[0][0].dtype)
            xv = jnp.zeros_like(xk)
            for i, (k_, v_) in enumerate(xkv_list):
                xk = xk.at[i].set(k_)
                xv = xv.at[i].set(v_)
            xkv = (xk, xv)
        self.call_counts["decode_batch"] += 1
        (self.k_pool, self.v_pool, live_ssm, live_conv,
         logits) = _decode_impl(
            self._spec, self.params, self.adapter_layers, self.k_pool,
            self.v_pool, self.live_ssm, self.live_conv, jnp.asarray(tok),
            jnp.asarray(pos), jnp.asarray(bt), jnp.asarray(ln),
            jnp.asarray(ad), jnp.asarray(rs), jnp.asarray(wb),
            jnp.asarray(wo), xkv)
        if self.Ls:
            self.live_ssm, self.live_conv = live_ssm, live_conv
        return np.asarray(logits[:B])

    # ------------------------------------------------------------------
    # SSM state snapshot / restore
    # ------------------------------------------------------------------
    def snapshot_boundary(self, boundary, c_idx: int, slot: int):
        b_ssm, b_conv = boundary
        self.snap_ssm = self.snap_ssm.at[:, slot].set(b_ssm[:, c_idx])
        self.snap_conv = self.snap_conv.at[:, slot].set(b_conv[:, c_idx])

    def snapshot_live(self, run_slot: int, slot: int):
        self.snap_ssm = self.snap_ssm.at[:, slot].set(
            self.live_ssm[:, run_slot])
        self.snap_conv = self.snap_conv.at[:, slot].set(
            self.live_conv[:, run_slot])

    def restore_state(self, slot: int, run_slot: int):
        self.live_ssm = self.live_ssm.at[:, run_slot].set(
            self.snap_ssm[:, slot])
        self.live_conv = self.live_conv.at[:, run_slot].set(
            self.snap_conv[:, slot])

    def reset_live(self, run_slot: int):
        self.live_ssm = self.live_ssm.at[:, run_slot].set(0.0)
        self.live_conv = self.live_conv.at[:, run_slot].set(0.0)
