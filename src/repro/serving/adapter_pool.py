"""Dynamic adapter lifecycle — the paged adapter-slot pool.

The engine used to freeze its adapter set at construction: one
equal-rank ``stack_adapters`` call, every adapter permanently resident,
nothing registerable afterwards.  This module makes adapters a *paged,
cached resource* exactly like KV blocks (S-LoRA's unified-paging
insight, arXiv 2311.03285): a host-side registry of arbitrarily many
adapters backs a small fixed pool of **device-resident slots**, and the
scheduler moves adapters through the slots as requests come and go.

Layout
------
``layers`` is the per-layer stacked A/B tensor list the model runner's
jitted step consumes directly (leaves ``(S+1, d, R)`` / ``(S+1, R, out)``
— slot 0 is the permanently-zero adapter, R the bucketed slot rank).
Registering an adapter rank-pads its weights into the bucket shape
(``core.alora.pad_adapter_rank`` — exact, zero-extension) and keeps them
host-side; residency means the weights have been scattered into slot
``s`` of every layer tensor.  The list object is shared with the runner,
so slot installs are visible to the next step without re-plumbing.

Per-registration state machine
------------------------------
::

                 register
                    │
                    ▼
   ┌──────────── HOST-ONLY ◄────────────────────┐
   │ prefetch       │ acquire (admission)       │ evict (LRU, pins==0)
   │                ▼                           │
   └─────────► PREFETCHED ──install──► RESIDENT─┘
                              (slot s)  pins>=0
                                          │ ▲
                                 release  │ │ acquire (hit)
                                 (finish/ ▼ │  pins+=1
                                  preempt)

* ``prefetch(uid)`` — scheduler-driven, issued while a request waits in
  the queue: ``jax.device_put`` of the padded weights.  The transfer is
  **async** (JAX dispatch); by the time the request is admitted and its
  first mixed step runs, the H2D copy has overlapped with host-side
  scheduling — adapter churn never blocks the one-call-per-step path.
  Staged-but-not-installed weights live in a **bounded staging tier**:
  at most ``staging_budget`` registrations may hold a device staging
  copy at once (a prefetch past the budget is deferred, never a second
  resident-sized HBM bill), and a stage that no admission ever claims
  expires after ``staging_ttl`` scheduler ticks — a prefetch issued for
  a request that is cancelled, drained or routed to another replica can
  no longer pin a full weight copy in HBM forever.  ``tick()`` (called
  once per engine step) drives the expiry clock; every refreshing
  ``prefetch`` call resets a stage's age.
* ``acquire(uid)`` — at admission: pins the adapter's slot (ref count),
  installing it first if not resident (allocating a free slot or
  evicting the least-recently-used *unpinned* one).  The install
  scatters the staged weights into the slot stack and drops the staging
  copy — residency costs one copy of the weights.  Returns ``None``
  when every slot is pinned — the scheduler keeps the request queued
  behind eviction.
* ``release(uid)`` — at request finish/preemption: unpin.  The slot
  stays resident (warm) until LRU eviction needs it.
* Evicted slots keep their stale weights until the next install; this is
  safe because a token's adapter index only ever points at a slot pinned
  by that token's own running request.

Cache identity: registrations are keyed by ``uid = name#vN`` (version
monotonic per pool).  Block hashes salt on the uid, never the slot
index and never the bare name — slot reuse after eviction, and
re-registration of a name with different weights, can therefore never
alias prefix-cache entries across adapters.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.alora import (
    AdapterSpec,
    adapter_rank_of,
    pad_adapter_rank,
    per_layer_adapters,
    zero_adapter_weights,
)
from repro.obs.tracer import Tracer
from repro.serving.metrics import AdapterPoolStats

Params = Dict[str, Any]


def rank_bucket(rank: int, lo: int = 8) -> int:
    """Pow2 rank bucket (min ``lo``) — the slot shape ranks pad into."""
    v = lo
    while v < rank:
        v *= 2
    return v


@partial(jax.jit, static_argnums=(3,), donate_argnums=(0,))
def _slot_scatter(pool_leaf, w, slot, out_sharding=None):
    """In-place slot write: donating the stack buffer lets XLA alias the
    output onto it, so an install costs O(one adapter's weights) instead
    of a fresh copy of the whole (S+1)-wide stack per leaf.
    ``out_sharding`` (sharded pools) pins the result to the slot-stack
    layout so installs can never reshard the stack the jitted mixed step
    was compiled against."""
    out = pool_leaf.at[slot].set(w.astype(pool_leaf.dtype))
    if out_sharding is not None:
        out = jax.lax.with_sharding_constraint(out, out_sharding)
    return out


@dataclass
class AdapterRegistration:
    spec: AdapterSpec
    uid: str
    host_layers: List[Params]               # per-layer, rank-padded, host
    device_layers: Optional[List[Params]] = None   # prefetched (device)
    slot: Optional[int] = None              # resident slot, if any
    pins: int = 0                           # running requests holding it


class AdapterPool:
    """Fixed device slot pool + host registry (see module docstring)."""

    def __init__(self, cfg: ModelConfig, *, num_slots: int, slot_rank: int,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 tracer: Optional[Tracer] = None,
                 staging_budget: Optional[int] = None,
                 staging_ttl: int = 64,
                 evict_policy: Optional[
                     Callable[[Sequence[str]], str]] = None):
        assert num_slots >= 1 and slot_rank >= 1
        assert staging_budget is None or staging_budget >= 1
        assert staging_ttl >= 1
        self.cfg = cfg
        # trace recorder shared with the owning engine (adapter-lifecycle
        # events land on the "pool" track); a disabled one standalone
        self.tracer = tracer if tracer is not None \
            else Tracer(enabled=False)
        self.num_slots = num_slots
        self.slot_rank = slot_rank
        self.mesh = mesh
        # per-layer stacked tensors, leading dim num_slots+1, slot 0 zero.
        # THE list object is shared with the model runner — entries are
        # replaced in place on install, never the list itself.
        zero = zero_adapter_weights(cfg, slot_rank)
        stacked = jax.tree.map(
            lambda a: jnp.zeros(a.shape[:2] + (num_slots + 1,)
                                + a.shape[2:], a.dtype), zero)
        self.layers: List[Params] = per_layer_adapters(cfg, stacked)
        # TP layout over EngineConfig.mesh: A replicated, B column-
        # parallel on its output dim (distributed.sharding, "Sharded
        # serving").  _slot_shardings pin the stacks; _weight_shardings
        # (the same specs minus the slot axis) are what prefetch
        # device_puts host weights into — the staged copy already lives
        # in the sharded slot layout, so an install is a local scatter.
        self._slot_shardings: Optional[List[Params]] = None
        self._weight_shardings: Optional[List[Params]] = None
        if mesh is not None:
            from repro.distributed import sharding as shd
            from jax.sharding import PartitionSpec as P
            self._slot_shardings, self._weight_shardings = [], []
            for li, lw in enumerate(self.layers):
                shape = jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), lw)
                specs = shd.adapter_slot_specs(cfg, shape, mesh=mesh)
                named = shd.to_named(specs, mesh)
                self.layers[li] = jax.device_put(lw, named)
                self._slot_shardings.append(named)
                self._weight_shardings.append(jax.tree.map(
                    lambda s: jax.sharding.NamedSharding(
                        mesh, P(*tuple(s)[1:])),
                    specs, is_leaf=lambda x: isinstance(x, P)))
        self._by_uid: Dict[str, AdapterRegistration] = {}
        self._by_name: Dict[str, str] = {}
        self._versions: Dict[str, int] = {}
        self._free: List[int] = list(range(1, num_slots + 1))
        # residency recency: uid -> None, least-recently-acquired first
        self._lru: "OrderedDict[str, None]" = OrderedDict()
        # slot eviction policy hook: given the unpinned resident uids in
        # least-recently-acquired-first order, returns the victim uid.
        # None = LRU (take the first candidate).
        self.evict_policy = evict_policy
        # staging tier: uid -> last-touched tick for every registration
        # currently holding a device staging copy (reg.device_layers).
        # Bounded by staging_budget; entries untouched for > staging_ttl
        # ticks are dropped by tick().
        self.staging_budget = (staging_budget if staging_budget is not None
                               else num_slots)
        self.staging_ttl = staging_ttl
        self._staged: "OrderedDict[str, int]" = OrderedDict()
        self._tick = 0
        # lifecycle counters (AdapterPoolStats)
        self.prefetch_issued = 0
        self.prefetch_hits = 0
        self.resident_hits = 0
        self.installs = 0
        self.evictions = 0
        self.acquire_fails = 0
        self.stalled_installs = 0
        self.staged_dropped = 0
        self.prefetch_deferred = 0

    # ------------------------------------------------------------------
    # registry
    # ------------------------------------------------------------------
    def register(self, spec: AdapterSpec, weights: Params) -> str:
        """Register an adapter at any time; returns its ``uid``.

        ``weights``: segment-stacked tree (``init_adapter_weights``
        layout) of any rank ≤ the pool's slot rank."""
        if spec.name in self._by_name:
            raise ValueError(f"adapter {spec.name!r} already registered; "
                             "unregister it first")
        r = adapter_rank_of(weights)
        if r > self.slot_rank:
            raise ValueError(
                f"adapter {spec.name!r} rank {r} exceeds the pool's slot "
                f"rank bucket {self.slot_rank}; construct the engine with "
                f"a larger EngineConfig.adapter_slot_rank")
        ver = self._versions.get(spec.name, 0) + 1
        self._versions[spec.name] = ver
        uid = f"{spec.name}#v{ver}"
        padded = pad_adapter_rank(weights, self.slot_rank)
        host = [jax.tree.map(np.asarray, lw)
                for lw in per_layer_adapters(self.cfg, padded)]
        self._by_uid[uid] = AdapterRegistration(spec=spec, uid=uid,
                                                host_layers=host)
        self._by_name[spec.name] = uid
        return uid

    def unregister(self, name: str) -> None:
        """Drop a registration.  Its slot (if resident) frees immediately;
        stale weights are overwritten by the next install."""
        uid = self._by_name.get(name)
        if uid is None:
            raise KeyError(name)
        reg = self._by_uid[uid]
        if reg.pins:
            raise RuntimeError(f"adapter {uid} still pinned by "
                               f"{reg.pins} running request(s)")
        if reg.device_layers is not None:
            self._drop_stage(uid, "unregister")
        del self._by_name[name]
        del self._by_uid[uid]
        if reg.slot is not None:
            self._free.append(reg.slot)
            self._lru.pop(uid, None)

    def uid_of(self, name: str) -> str:
        return self._by_name[name]

    def get(self, uid: str) -> AdapterRegistration:
        return self._by_uid[uid]

    @property
    def registered(self) -> List[str]:
        return list(self._by_name)

    # ------------------------------------------------------------------
    # residency
    # ------------------------------------------------------------------
    def prefetch(self, uid: str) -> bool:
        """Issue the async host→device transfer ahead of admission.
        Idempotent: refreshes the stage's TTL while the weights are
        already staged (the scheduler re-calls this every step for
        queued requests), a no-op while resident.  Returns ``False``
        when the staging tier is at its budget and the transfer was
        deferred — the scheduler simply retries next step, by which
        time an install or expiry may have freed a stage."""
        reg = self._by_uid[uid]
        if reg.slot is not None:
            return True
        if reg.device_layers is not None:
            self._staged[uid] = self._tick          # refresh TTL
            self._staged.move_to_end(uid)
            return True
        if len(self._staged) >= self.staging_budget:
            self.prefetch_deferred += 1
            if self.tracer.enabled:
                self.tracer.event("pool", "prefetch_deferred", None,
                                  {"uid": uid})
                self.tracer.count("adapter_prefetch_deferred_total")
            return False
        self._stage(reg)
        self.prefetch_issued += 1
        if self.tracer.enabled:
            self.tracer.event("pool", "prefetch", None, {"uid": uid})
            self.tracer.count("adapter_prefetch_total")
        return True

    def _stage(self, reg: AdapterRegistration) -> None:
        """Device-put ``reg``'s host weights into the staging tier."""
        if self._weight_shardings is not None:
            # sharded pool: stage the weights directly in the slot-stack
            # layout (A replicated, B column-parallel) so the install
            # scatter is shard-local
            reg.device_layers = [
                jax.tree.map(jax.device_put, lw, self._weight_shardings[li])
                for li, lw in enumerate(reg.host_layers)]
        else:
            reg.device_layers = [jax.tree.map(jax.device_put, lw)
                                 for lw in reg.host_layers]
        self._staged[reg.uid] = self._tick
        self._staged.move_to_end(reg.uid)

    def tick(self) -> None:
        """Advance the staging clock one scheduler step and expire
        stages nothing claimed for ``staging_ttl`` ticks — the fix for
        the prefetch leak where a stage issued for a request that never
        admits (cancelled, drained, routed to another replica) pinned a
        full weight copy in HBM forever."""
        self._tick += 1
        expired = [uid for uid, touched in self._staged.items()
                   if self._tick - touched > self.staging_ttl]
        for uid in expired:
            self._drop_stage(uid, "expired")

    def drop_unclaimed_stages(self) -> int:
        """Drop EVERY unclaimed staging copy now; returns the count.

        The TTL expiry in ``tick`` only runs while the engine is being
        stepped — a drained replica (``Router.stop_replica``) never
        ticks again, so stages prefetched for requests that were
        re-routed away would pin full weight copies in HBM for the
        process lifetime.  Dropping is always safe: a later stalled
        install re-stages on demand.
        """
        dropped = list(self._staged)
        for uid in dropped:
            self._drop_stage(uid, "drain")
        return len(dropped)

    def _drop_stage(self, uid: str, reason: str) -> None:
        reg = self._by_uid.get(uid)
        if reg is not None:
            reg.device_layers = None
        self._staged.pop(uid, None)
        self.staged_dropped += 1
        if self.tracer.enabled:
            self.tracer.event("pool", "stage_drop", None,
                              {"uid": uid, "reason": reason})
            self.tracer.count("adapter_staged_dropped_total")

    def acquire(self, uid: str) -> Optional[int]:
        """Pin ``uid``'s slot for a scheduled request, installing it
        first if needed.  Returns the slot index, or ``None`` when every
        slot is pinned (caller queues behind eviction)."""
        reg = self._by_uid[uid]
        if reg.slot is None:
            slot = self._take_slot()
            if slot is None:
                self.acquire_fails += 1
                if self.tracer.enabled:
                    self.tracer.event("pool", "acquire_fail", None,
                                      {"uid": uid})
                    self.tracer.count("adapter_acquire_fails_total")
                return None
            if reg.device_layers is None:
                # weights were never prefetched (or the prefetch was
                # deferred at the staging budget) — the H2D copy is
                # issued here, on the admission path (still async, but
                # without the queue-time head start).  Staged directly,
                # bypassing the budget: the install below claims the
                # copy in the same call, so it never lingers.
                self.stalled_installs += 1
                if self.tracer.enabled:
                    self.tracer.event("pool", "stall", None, {"uid": uid})
                    self.tracer.count("adapter_stalls_total")
                self._stage(reg)
            else:
                self.prefetch_hits += 1      # install found staged weights
            self._install(reg, slot)
        else:
            self.resident_hits += 1
        reg.pins += 1
        self._lru[uid] = None
        self._lru.move_to_end(uid)
        return reg.slot

    def release(self, uid: str) -> None:
        """Unpin at request finish/preemption; slot stays warm."""
        reg = self._by_uid[uid]
        assert reg.pins > 0, f"release of unpinned adapter {uid}"
        reg.pins -= 1

    def _take_slot(self) -> Optional[int]:
        if self._free:
            return self._free.pop()
        # unpinned resident adapters, least recently acquired first
        candidates = [uid for uid in self._lru
                      if self._by_uid[uid].pins == 0]
        if not candidates:
            return None
        if self.evict_policy is None:
            uid = candidates[0]              # LRU
        else:
            uid = self.evict_policy(candidates)
            assert uid in candidates, \
                f"evict_policy returned non-candidate {uid!r}"
        victim = self._by_uid[uid]
        self._lru.pop(uid)
        slot, victim.slot = victim.slot, None
        self.evictions += 1
        if self.tracer.enabled:
            self.tracer.event("pool", "evict", None,
                              {"uid": uid, "slot": slot})
            self.tracer.count("adapter_evictions_total")
        return slot

    def _install(self, reg: AdapterRegistration, slot: int) -> None:
        s = jnp.asarray(slot, jnp.int32)
        for li, lw in enumerate(reg.device_layers):
            if self._slot_shardings is not None:
                self.layers[li] = jax.tree.map(
                    lambda pool, w, osh: _slot_scatter(pool, w, s, osh),
                    self.layers[li], lw, self._slot_shardings[li])
            else:
                self.layers[li] = jax.tree.map(
                    lambda pool, w: _slot_scatter(pool, w, s),
                    self.layers[li], lw)
        # the staging copy has been scattered into the slot stack; drop
        # it so residency costs one copy of the weights, not two
        reg.device_layers = None
        self._staged.pop(reg.uid, None)      # claimed, not leaked
        reg.slot = slot
        self.installs += 1
        if self.tracer.enabled:
            self.tracer.event("pool", "install", None,
                              {"uid": reg.uid, "slot": slot})
            self.tracer.count("adapter_installs_total")

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    @property
    def occupancy(self) -> int:
        return self.num_slots - len(self._free)

    def pinned_slots(self) -> int:
        return sum(1 for r in self._by_uid.values()
                   if r.slot is not None and r.pins > 0)

    def residency(self) -> Dict[str, bool]:
        """Name → device-resident (installed in a slot) snapshot.  The
        serving router's adapter-affinity signal: routing a request to a
        replica where its adapter is already installed skips the
        eviction+install admission charge entirely."""
        return {name: self._by_uid[uid].slot is not None
                for name, uid in self._by_name.items()}

    def affinity_of(self, uid: str) -> int:
        """Admission-affinity class of a registration: ``2`` resident
        (slot installed — acquire is a pin), ``1`` staged (weights on
        device awaiting install), ``0`` host-only (acquire stalls on
        the H2D copy).  The admission scheduler's ordering key and,
        name-resolved via :meth:`affinity`, the router's placement
        signal."""
        reg = self._by_uid[uid]
        if reg.slot is not None:
            return 2
        if reg.device_layers is not None:
            return 1
        return 0

    def affinity(self, name: str) -> int:
        """Name-keyed :meth:`affinity_of` (0 for unknown names)."""
        uid = self._by_name.get(name)
        return 0 if uid is None else self.affinity_of(uid)

    def can_take_slot(self) -> bool:
        """Would :meth:`_take_slot` succeed right now — a free slot, or
        an unpinned resident victim?  The admission scheduler's cheap
        gate: a non-resident candidate is skipped without issuing a
        doomed acquire (which would count an ``acquire_fails`` per scan
        for a failure the scheduler can already see)."""
        return bool(self._free) or any(
            self._by_uid[uid].pins == 0 for uid in self._lru)

    @property
    def staged_now(self) -> int:
        """Registrations currently holding a device staging copy."""
        return len(self._staged)

    def stats(self) -> AdapterPoolStats:
        return AdapterPoolStats(
            num_slots=self.num_slots,
            num_registered=len(self._by_name),
            occupancy=self.occupancy,
            prefetch_issued=self.prefetch_issued,
            prefetch_hits=self.prefetch_hits,
            resident_hits=self.resident_hits,
            installs=self.installs,
            evictions=self.evictions,
            acquire_fails=self.acquire_fails,
            stalled_installs=self.stalled_installs,
            staged_now=self.staged_now,
            staged_dropped=self.staged_dropped,
            prefetch_deferred=self.prefetch_deferred,
        )
