"""Multi-turn, multi-adapter pipeline drivers (paper §4.1).

The atomic pattern: query base model M1 with prompt x → response y;
query adapter A1 with (x+y) → evaluation r; optionally feed (x+y+r) back
into M1.  Baseline = the same pipeline with vanilla-LoRA adapters (no
cross-model cache reuse); ours = aLoRA adapters.

Each driver returns per-stage request ids so benchmarks can aggregate
stage metrics exactly like the paper (evaluation-step metrics are the
headline numbers)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

import numpy as np

from repro.serving.engine import Engine
from repro.serving.metrics import MetricsAggregate


@dataclass
class PipelineResult:
    base_ids: List[int] = field(default_factory=list)
    eval_ids: List[int] = field(default_factory=list)   # adapter step
    final_ids: List[int] = field(default_factory=list)  # second base call

    def stage_metrics(self, eng: Engine, stage: str) -> MetricsAggregate:
        ids = {"base": self.base_ids, "eval": self.eval_ids,
               "final": self.final_ids}[stage]
        return eng.metrics_for(ids)


def _rand_prompt(rng: np.random.RandomState, n: int, vocab: int,
                 lo: int = 10) -> List[int]:
    return list(rng.randint(lo, vocab, n))


def base_adapter(eng: Engine, *, adapter_names: Sequence[str],
                 prompt_len: int, gen_len: int, eval_len: int,
                 batch: int = 1, seed: int = 0,
                 feed_back_to_base: bool = False,
                 final_len: int = 16) -> PipelineResult:
    """Sync base→adapter (→base) pipeline, ``batch`` parallel instances.

    With >1 adapter names the adapters are invoked in parallel on the
    same (x+y) context (paper §4.4.1)."""
    rng = np.random.RandomState(seed)
    vocab = eng.cfg.vocab_size
    res = PipelineResult()
    prompts = [_rand_prompt(rng, prompt_len, vocab) for _ in range(batch)]

    for x in prompts:
        res.base_ids.append(eng.submit(x, gen_len))
    eng.run_until_idle()

    evals: Dict[int, List[List[int]]] = {}
    for bi, (rid, x) in enumerate(zip(res.base_ids, prompts)):
        y = eng.request(rid).output_tokens
        evals[bi] = []
        for name in adapter_names:
            inv = list(eng.adapters[name].spec.invocation_tokens or ())
            p = x + y + inv
            res.eval_ids.append(eng.submit(p, eval_len, adapter_name=name))
            evals[bi].append(p)
    eng.run_until_idle()

    if feed_back_to_base:
        k = len(adapter_names)
        for bi, (rid, x) in enumerate(zip(res.base_ids, prompts)):
            y = eng.request(rid).output_tokens
            ctx = x + y
            for j, eid in enumerate(
                    res.eval_ids[bi * k:(bi + 1) * k]):
                ctx = ctx + eng.request(eid).output_tokens
            res.final_ids.append(eng.submit(ctx, final_len))
        eng.run_until_idle()
    return res


def adapter_base(eng: Engine, *, adapter_name: str, prompt_len: int,
                 eval_len: int, gen_len: int, batch: int = 1,
                 seed: int = 0) -> PipelineResult:
    """Sync adapter→base pipeline (paper App. C): an adapter screens the
    prompt, then the base model generates; the base reuses the adapter's
    pre-activation prefill blocks (two-way reuse)."""
    rng = np.random.RandomState(seed)
    vocab = eng.cfg.vocab_size
    res = PipelineResult()
    inv = list(eng.adapters[adapter_name].spec.invocation_tokens or ())
    prompts = [_rand_prompt(rng, prompt_len, vocab) for _ in range(batch)]

    for x in prompts:
        res.eval_ids.append(
            eng.submit(x + inv, eval_len, adapter_name=adapter_name))
    eng.run_until_idle()

    for rid, x in zip(res.eval_ids, prompts):
        r = eng.request(rid).output_tokens
        res.final_ids.append(eng.submit(x + r, gen_len))
    eng.run_until_idle()
    return res


def async_base_adapter(eng: Engine, *, adapter_name: str,
                       arrival_rate: float, num_requests: int,
                       prompt_len: int, gen_len: int, eval_len: int,
                       seed: int = 0) -> PipelineResult:
    """Async base→adapter pipeline: pipeline instances arrive as a
    Poisson process with rate ``arrival_rate`` (paper §4.3).  The adapter
    request is submitted the moment its base request completes."""
    rng = np.random.RandomState(seed)
    vocab = eng.cfg.vocab_size
    inv = list(eng.adapters[adapter_name].spec.invocation_tokens or ())
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, num_requests))
    res = PipelineResult()
    prompts = {}
    for t in arrivals:
        x = _rand_prompt(rng, prompt_len, vocab)
        rid = eng.submit(x, gen_len, arrival_time=float(t))
        prompts[rid] = x
        res.base_ids.append(rid)

    submitted = set()
    for _ in range(10_000_000):
        if not (eng.pending or eng.waiting or eng.running) \
                and len(submitted) == len(res.base_ids):
            break
        eng.step()
        for rid in res.base_ids:
            if rid in submitted:
                continue
            req = eng.request(rid)
            if req.t_done is not None:
                x = prompts[rid]
                p = x + req.output_tokens + inv
                res.eval_ids.append(
                    eng.submit(p, eval_len, adapter_name=adapter_name,
                               arrival_time=req.t_done))
                submitted.add(rid)
    eng.run_until_idle()
    return res
