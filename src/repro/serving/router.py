"""Multi-replica serving tier: a cache-affinity router over N engines.

The paper's system (§3) serves many adapters from ONE engine; this
module scales it out: ``Router`` fronts N in-process :class:`Engine`
replicas — each with its own device pools, prefix cache and adapter
slots — and places every submission with an aLoRA-aligned locality
score instead of blind load balancing.

Placement (``policy="affinity"``) ranks replicas by

1. **cached-prefix depth** — ``Engine.cached_prefix_tokens``, the same
   chained base-aligned block hashes admission matches on
   (``core.block_hash``, adapter-uid-salted).  Because hashing is
   base-aligned, an aLoRA turn scores hits against blocks a sibling
   adapter or the base model prefilled on that replica — exactly the
   cross-model reuse the paper's single-engine cache exploits, lifted
   to the placement decision;
2. **adapter residency** — a replica with the request's adapter already
   installed in a device slot skips the eviction+install charge;
3. **least outstanding tokens** — remaining prompt+decode work, so cold
   requests spread across the fleet.

Ties break toward the lowest replica index (deterministic placement —
the R-replica router is token-for-token reproducible against a
single-engine oracle, which the test suite asserts).

Multi-turn pipelines additionally pass ``session=``: the first turn
pins the session to its scored replica and later turns follow the pin,
so a conversation's growing prefix chain always lands where its blocks
live.  ``policy="round_robin"`` ignores all signals (the A/B baseline
``benchmarks/bench_router.py`` measures the affinity win against).

``stop_replica`` drains a replica without losing work: not-yet-admitted
requests re-route to the surviving replicas (original arrival times
kept), admitted ones finish on the draining replica — the router keeps
stepping it until it empties, then stops placing on it.

The router is host-side python over the replica surface only — no
device work of its own, every probe non-acquiring.  Replica-local
metrics stay per-engine; fleet aggregation goes through
``serving.metrics.merge_aggregates`` (overlapped wall-clock is counted
once via min-arrival/max-done endpoints, never summed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.alora import AdapterSpec
from repro.obs.tracer import Tracer
from repro.serving.engine import Engine
from repro.serving.metrics import MetricsAggregate, merge_aggregates
from repro.serving.request import Request

POLICIES = ("affinity", "round_robin")


@dataclass(frozen=True)
class Placement:
    """One admission decision (``Router.placements`` keeps the log the
    router tests and ``bench_router`` introspect)."""
    req_id: int                     # router-global request id
    replica: int
    cached_tokens: int              # scored prefix depth at placement
    adapter_resident: bool
    via_session: bool               # pinned by a sticky session


class Router:
    """Cache-affinity admission router over in-process engine replicas.

    All replicas must be built from the same config/params (the fleet is
    a data-parallel scale-out of one model); adapters are registered
    THROUGH the router so every replica assigns the same registry uid —
    the uid salts block hashes, so uid agreement is what keeps a
    session's prefix chain portable across replicas.
    """

    def __init__(self, replicas: Sequence[Engine], *,
                 policy: str = "affinity"):
        if not replicas:
            raise ValueError("router needs at least one replica")
        if policy not in POLICIES:
            raise ValueError(
                f"unknown routing policy {policy!r}: expected one of "
                f"{POLICIES}")
        self.replicas: List[Engine] = list(replicas)
        self.policy = policy
        # fleet tracing: stamp each replica's tracer with its fleet
        # position (per-replica Perfetto tracks) and keep a router-own
        # tracer (replica=-1 → the "router" process) for placement
        # decisions; export via repro.obs.export over
        # [*(eng.tracer for eng in replicas), router.tracer]
        self.tracer = Tracer(replica=-1)
        for i, eng in enumerate(self.replicas):
            eng.tracer.set_replica(i)
        self._stopped = [False] * len(self.replicas)
        self._rr_next = 0
        self._next_id = 0
        # router-global req id -> (replica index, replica-local req id)
        self._routes: Dict[int, Tuple[int, int]] = {}
        self._sessions: Dict[Hashable, int] = {}
        self.placements: List[Placement] = []
        self.reroutes = 0               # drain-time resubmissions

    # ------------------------------------------------------------------
    # adapter lifecycle: fleet-wide, uid-aligned
    # ------------------------------------------------------------------
    def register_adapter(self, spec: AdapterSpec, weights) -> str:
        """Register on EVERY replica; returns the (shared) registry uid.

        Registration is fleet-wide even on stopped replicas so a later
        restart never desynchronizes the uid counters; the uids must
        agree because block hashes salt on them — a divergent fleet
        would silently never cross-match.
        """
        uids = {eng.register_adapter(spec, weights)
                for eng in self.replicas}
        assert len(uids) == 1, f"replica uid divergence: {sorted(uids)}"
        return uids.pop()

    def unregister_adapter(self, name: str) -> None:
        for eng in self.replicas:
            eng.unregister_adapter(name)

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def _live_indices(self) -> List[int]:
        live = [i for i in range(len(self.replicas))
                if not self._stopped[i]]
        if not live:
            raise RuntimeError("every replica is stopped")
        return live

    def _score(self, i: int, prompt: Sequence[int],
               adapter_name: Optional[str],
               salt: Tuple) -> Tuple[int, int, int]:
        """(cached prefix tokens, adapter affinity, -outstanding): the
        affinity ranking, compared lexicographically, max wins.  The
        adapter term is the graded pool class (2 slot-resident, 1
        staged, 0 host-only) — a replica that already staged the
        weights beats one that must start the H2D copy from scratch."""
        eng = self.replicas[i]
        cached = eng.cached_prefix_tokens(prompt, adapter_name, salt)
        affinity = 0
        if adapter_name is not None:
            affinity = eng.adapter_affinity(adapter_name)
        return (cached, affinity, -eng.outstanding_tokens())

    def _place(self, prompt: Sequence[int], adapter_name: Optional[str],
               salt: Tuple,
               session: Optional[Hashable]) -> Tuple[int, int, bool]:
        """Pick a replica; returns (index, scored cached tokens,
        placed-via-session)."""
        if session is not None:
            pinned = self._sessions.get(session)
            if pinned is not None and not self._stopped[pinned]:
                cached = self.replicas[pinned].cached_prefix_tokens(
                    prompt, adapter_name, salt)
                return pinned, cached, True
        live = self._live_indices()
        if self.policy == "round_robin":
            # cycle over live replicas, blind to locality
            k = self._rr_next % len(live)
            self._rr_next += 1
            idx, cached = live[k], 0
        else:
            best, best_score = live[0], None
            for i in live:
                s = self._score(i, prompt, adapter_name, salt)
                if best_score is None or s > best_score:
                    best, best_score = i, s
            idx, cached = best, best_score[0]
        if session is not None:
            self._sessions[session] = idx
        return idx, cached, False

    def submit(self, prompt: Sequence[int], max_new_tokens: int, *,
               adapter_name: Optional[str] = None,
               arrival_time: Optional[float] = None,
               prefix_embeds: Optional[np.ndarray] = None,
               frame_embeds: Optional[np.ndarray] = None,
               salt: Tuple = (),
               session: Optional[Hashable] = None) -> int:
        """Place + submit one request; returns a ROUTER-global id.

        Same surface as ``Engine.submit`` plus ``session``: a hashable
        key pinning every request that shares it to one replica (sticky
        multi-turn routing).  The global id is stable across drain-time
        rerouting — always resolve results through the router.
        """
        idx, cached, via_session = self._place(prompt, adapter_name,
                                               salt, session)
        eng = self.replicas[idx]
        local = eng.submit(prompt, max_new_tokens,
                           adapter_name=adapter_name,
                           arrival_time=arrival_time,
                           prefix_embeds=prefix_embeds,
                           frame_embeds=frame_embeds, salt=salt)
        gid = self._next_id
        self._next_id += 1
        self._routes[gid] = (idx, local)
        resident = False
        if adapter_name is not None:
            resident = eng.adapter_residency().get(adapter_name, False)
        self.placements.append(Placement(
            req_id=gid, replica=idx, cached_tokens=cached,
            adapter_resident=resident, via_session=via_session))
        if self.tracer.enabled:
            self.tracer.event("router", "placement", None,
                              {"req_id": gid, "replica": idx,
                               "cached_tokens": cached,
                               "adapter_resident": resident,
                               "via_session": via_session})
            self.tracer.count("placements_total")
            self.tracer.count(f"placements_replica_{idx}_total")
        return gid

    # ------------------------------------------------------------------
    # drain / failover
    # ------------------------------------------------------------------
    def stop_replica(self, idx: int) -> int:
        """Stop placing on replica ``idx`` and re-route its queued work.

        Requests still in the replica's arrival/admission queues hold no
        device state — they resubmit to the surviving replicas through
        the normal placement path with their original arrival times,
        adapters and salts, keeping their router-global ids.  Admitted
        requests keep draining on the stopped replica (``step`` keeps
        stepping it until idle), so no request — and no sampled token —
        is ever lost.  Returns the number of re-routed requests.
        """
        if self._stopped[idx]:
            return 0
        if not [i for i in self._live_indices() if i != idx]:
            # refuse BEFORE flipping the flag — a failed stop must leave
            # the fleet routable
            raise RuntimeError("cannot stop the last live replica")
        self._stopped[idx] = True
        eng = self.replicas[idx]
        displaced = list(eng.pending) + list(eng.waiting)
        eng.pending.clear()
        eng.waiting.clear()
        # drop the replica's unclaimed staging-tier prefetches NOW: a
        # stopped replica only steps until its admitted work drains, so
        # the pool's TTL expiry (tick) may never run again and stages
        # prefetched for the re-routed queue would pin HBM forever
        if eng.adapter_pool is not None:
            eng.adapter_pool.drop_unclaimed_stages()
        # forget sessions pinned to the stopped replica; the next turn
        # re-scores (its prefix blocks are gone with the replica anyway)
        self._sessions = {s: r for s, r in self._sessions.items()
                          if r != idx}
        by_local = {local: gid for gid, (r, local) in self._routes.items()
                    if r == idx}
        for req in displaced:
            new_idx, cached, _ = self._place(
                req.prompt, req.adapter.name if req.adapter else None,
                req.salt, None)
            target = self.replicas[new_idx]
            local = target.submit(
                req.prompt, req.max_new_tokens,
                adapter_name=req.adapter.name if req.adapter else None,
                arrival_time=req.arrival_time,
                prefix_embeds=req.prefix_embeds,
                frame_embeds=req.frame_embeds, salt=req.salt)
            gid = by_local.get(req.req_id)
            if gid is not None:
                self._routes[gid] = (new_idx, local)
            self.reroutes += 1
        if self.tracer.enabled:
            self.tracer.event("router", "stop_replica", None,
                              {"replica": idx,
                               "rerouted": len(displaced)})
            self.tracer.count("reroutes_total", len(displaced))
        return len(displaced)

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    def step(self) -> float:
        """Step every replica with live work once.

        Replicas are independent engines on independent devices, so one
        fleet step advances them all; the returned wall-clock cost is
        the MAX over replica step times (they run concurrently in a real
        deployment — summing would double-count overlap, the same rule
        ``merge_aggregates`` applies to throughput).  Stopped replicas
        keep stepping until their admitted requests drain.
        """
        t = 0.0
        for eng in self.replicas:
            if not eng.idle:
                t = max(t, eng.step())
        return t

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.idle:
                return
            self.step()
        raise RuntimeError("router fleet did not drain")

    @property
    def idle(self) -> bool:
        return all(eng.idle for eng in self.replicas)

    # ------------------------------------------------------------------
    # Engine-surface proxies: the replicas are identically configured,
    # so the fleet's model config / adapter registry IS replica 0's —
    # with these the router is drop-in for the pipeline drivers
    # (serving/pipelines.py, launch/serve.py) that only touch the
    # submit/run_until_idle/request/metrics_for surface.
    # ------------------------------------------------------------------
    @property
    def cfg(self):
        return self.replicas[0].cfg

    @property
    def adapters(self):
        return self.replicas[0].adapters

    # ------------------------------------------------------------------
    # results / stats
    # ------------------------------------------------------------------
    def replica_of(self, req_id: int) -> int:
        return self._routes[req_id][0]

    def request(self, req_id: int) -> Request:
        idx, local = self._routes[req_id]
        return self.replicas[idx].request(local)

    def metrics_for(self, req_ids: Sequence[int]) -> MetricsAggregate:
        """Fleet aggregate over the given router-global ids: per-replica
        aggregates merged without double-counting overlapped wall-clock
        (fleet throughput uses the min-arrival→max-done makespan)."""
        by_replica: Dict[int, List[int]] = {}
        for gid in req_ids:
            idx, local = self._routes[gid]
            by_replica.setdefault(idx, []).append(local)
        parts = [self.replicas[idx].metrics_for(locals_)
                 for idx, locals_ in sorted(by_replica.items())]
        return merge_aggregates(parts)

    def per_replica_metrics(self, req_ids: Sequence[int]
                            ) -> Dict[int, MetricsAggregate]:
        """Replica index → aggregate over its share of ``req_ids``."""
        by_replica: Dict[int, List[int]] = {}
        for gid in req_ids:
            idx, local = self._routes[gid]
            by_replica.setdefault(idx, []).append(local)
        return {idx: self.replicas[idx].metrics_for(locals_)
                for idx, locals_ in sorted(by_replica.items())}

    def kv_hit_rate(self) -> float:
        """Fleet prefix-cache hit rate: summed hits over summed lookups
        (NOT a mean of per-replica rates — replicas see different
        admission counts under affinity routing)."""
        hits = total = 0
        for eng in self.replicas:
            mgr = eng.kv_mgr or eng.st_mgr
            hits += mgr.hits
            total += mgr.hits + mgr.misses
        return hits / total if total else 0.0
