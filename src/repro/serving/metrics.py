"""Serving metrics aggregation (paper Table 2), Prometheus-endpoint
equivalent: the engine records per-request stage timings; this module
aggregates them per pipeline stage for the benchmark tables."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List

import numpy as np

METRIC_KEYS = ("queue", "prefill", "decode", "ttft", "itl", "e2e",
               "inference", "cache_hit_frac")


@dataclass
class MetricsAggregate:
    n: int
    means: Dict[str, float]
    p50: Dict[str, float]
    p99: Dict[str, float]
    # tokens / makespan (max done − min arrival): the system's actual
    # wall-clock throughput under concurrency
    throughput_tok_per_s: float
    # tokens / Σ per-request e2e: a PER-REQUEST service rate.  This was
    # (wrongly) reported as throughput before — summing overlapped
    # request lifetimes double-counts wall-clock and underreports the
    # real rate whenever requests run concurrently.
    tok_per_req_s: float = 0.0
    # extensive totals + wall-clock endpoints, kept so aggregates MERGE
    # without double-counting overlapped wall-clock: the multi-replica
    # router's replicas run concurrently, so fleet throughput is
    # Σ tokens / (max done − min arrival) over the union — NEVER a sum
    # (or mean) of per-replica throughputs, which would count the same
    # wall-clock interval once per replica.  NaN endpoints mean the
    # source metrics carried no arrival/done timestamps.
    total_tokens: int = 0
    total_e2e: float = 0.0
    t_min_arrival: float = float("nan")
    t_max_done: float = float("nan")

    def row(self, keys: Iterable[str] = METRIC_KEYS) -> Dict[str, float]:
        """Means per metric key; an empty aggregate yields NaNs (never a
        KeyError — renderers show them as ``-``)."""
        return {k: self.means.get(k, float("nan")) for k in keys}


def aggregate(metrics: List[dict]) -> MetricsAggregate:
    if not metrics:
        return MetricsAggregate(0, {}, {}, {}, 0.0)
    means, p50, p99 = {}, {}, {}
    for k in METRIC_KEYS:
        vals = np.array([m[k] for m in metrics], dtype=np.float64)
        means[k] = float(vals.mean())
        p50[k] = float(np.percentile(vals, 50))
        p99[k] = float(np.percentile(vals, 99))
    total_tokens = sum(m["prompt_len"] + m["output_len"] for m in metrics)
    total_e2e = sum(m["e2e"] for m in metrics)
    tok_per_req = total_tokens / total_e2e if total_e2e else 0.0
    # wall-clock throughput over the batch's makespan; requests recorded
    # without endpoints (hand-built dicts) fall back to the per-request
    # rate rather than inventing a wall-clock
    t_lo = t_hi = float("nan")
    if all(m.get("arrival") is not None and m.get("done") is not None
           for m in metrics):
        t_lo = min(m["arrival"] for m in metrics)
        t_hi = max(m["done"] for m in metrics)
        makespan = t_hi - t_lo
        throughput = total_tokens / makespan if makespan > 0 \
            else tok_per_req
    else:
        throughput = tok_per_req
    return MetricsAggregate(
        n=len(metrics), means=means, p50=p50, p99=p99,
        throughput_tok_per_s=throughput, tok_per_req_s=tok_per_req,
        total_tokens=total_tokens, total_e2e=total_e2e,
        t_min_arrival=t_lo, t_max_done=t_hi)


def merge_aggregates(parts: List[MetricsAggregate]) -> MetricsAggregate:
    """Merge per-replica aggregates into one fleet aggregate.

    Replicas run CONCURRENTLY, so the fleet's wall-clock throughput is
    the union's Σ tokens over the union's makespan (earliest arrival →
    latest done across every part) — summing or averaging per-replica
    throughputs would count overlapped wall-clock once per replica and
    overstate the fleet rate.  Means merge exactly (n-weighted);
    percentiles merge as n-weighted means of the per-part percentiles —
    an APPROXIMATION (exact fleet percentiles need the raw per-request
    rows, which per-replica aggregates have already reduced away) that
    is exact when the parts are identically distributed.
    """
    parts = [p for p in parts if p.n]
    if not parts:
        return MetricsAggregate(0, {}, {}, {}, 0.0)
    if len(parts) == 1:
        return parts[0]
    n = sum(p.n for p in parts)

    def wmean(dicts: List[Dict[str, float]]) -> Dict[str, float]:
        keys = set().union(*dicts)
        return {k: sum(d.get(k, 0.0) * p.n for d, p in zip(dicts, parts))
                / n for k in keys}

    total_tokens = sum(p.total_tokens for p in parts)
    total_e2e = sum(p.total_e2e for p in parts)
    tok_per_req = total_tokens / total_e2e if total_e2e else 0.0
    arrivals = [p.t_min_arrival for p in parts]
    dones = [p.t_max_done for p in parts]
    t_lo = t_hi = float("nan")
    if not any(np.isnan(arrivals)) and not any(np.isnan(dones)):
        t_lo, t_hi = min(arrivals), max(dones)
        makespan = t_hi - t_lo
        throughput = total_tokens / makespan if makespan > 0 \
            else tok_per_req
    else:
        throughput = tok_per_req
    return MetricsAggregate(
        n=n,
        means=wmean([p.means for p in parts]),
        p50=wmean([p.p50 for p in parts]),
        p99=wmean([p.p99 for p in parts]),
        throughput_tok_per_s=throughput, tok_per_req_s=tok_per_req,
        total_tokens=total_tokens, total_e2e=total_e2e,
        t_min_arrival=t_lo, t_max_done=t_hi)


@dataclass
class AdapterPoolStats:
    """Adapter-lifecycle counters (the Prometheus-gauge equivalents for
    the dynamic adapter pool): how often weights moved, how full the
    slot pool ran, and whether admission ever stalled on weights."""
    num_slots: int = 0
    num_registered: int = 0
    occupancy: int = 0            # resident slots right now
    prefetch_issued: int = 0      # async H2D transfers started
    prefetch_hits: int = 0        # installs that found staged weights
    resident_hits: int = 0        # acquire found the slot warm
    installs: int = 0             # slot writes (scatter into the stack)
    evictions: int = 0            # LRU slot reclaims
    acquire_fails: int = 0        # admissions queued behind eviction
    stalled_installs: int = 0     # installs whose H2D was never prefetched

    def row(self) -> Dict[str, float]:
        return {k: float(getattr(self, k)) for k in (
            "num_slots", "num_registered", "occupancy", "prefetch_issued",
            "prefetch_hits", "resident_hits", "installs", "evictions",
            "acquire_fails", "stalled_installs")}


def speedup_table(baseline: MetricsAggregate, ours: MetricsAggregate,
                  keys: Iterable[str] = ("e2e", "ttft", "queue", "prefill",
                                         "decode")) -> Dict[str, float]:
    """Paper-style speedup factors (baseline=LoRA / ours=aLoRA)."""
    out = {}
    for k in keys:
        b, o = baseline.means.get(k, 0.0), ours.means.get(k, 0.0)
        out[k] = b / o if o > 0 else float("inf")
    return out
