"""Serving metrics aggregation (paper Table 2), Prometheus-endpoint
equivalent: the engine records per-request stage timings; this module
aggregates them per pipeline stage for the benchmark tables."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

import numpy as np

METRIC_KEYS = ("queue", "prefill", "decode", "ttft", "itl", "e2e",
               "inference", "cache_hit_frac")

# Per-metric sample reservoir bound carried on each aggregate so that
# ``merge_aggregates`` can recompute fleet percentiles EXACTLY from the
# union of per-request values instead of n-weighting per-part
# percentiles.  Deterministic first-N (not random sampling): benchmark
# runs are replayable and goldens must not wobble.  Exactness holds
# while every merged part carries a COMPLETE reservoir, i.e. each
# part's n ≤ RESERVOIR_MAX; beyond that the merge falls back to the
# n-weighted approximation it always used.
RESERVOIR_MAX = 1024


@dataclass
class MetricsAggregate:
    n: int
    means: Dict[str, float]
    p50: Dict[str, float]
    p99: Dict[str, float]
    # tokens / makespan (max done − min arrival): the system's actual
    # wall-clock throughput under concurrency
    throughput_tok_per_s: float
    # tokens / Σ per-request e2e: a PER-REQUEST service rate.  This was
    # (wrongly) reported as throughput before — summing overlapped
    # request lifetimes double-counts wall-clock and underreports the
    # real rate whenever requests run concurrently.
    tok_per_req_s: float = 0.0
    # extensive totals + wall-clock endpoints, kept so aggregates MERGE
    # without double-counting overlapped wall-clock: the multi-replica
    # router's replicas run concurrently, so fleet throughput is
    # Σ tokens / (max done − min arrival) over the union — NEVER a sum
    # (or mean) of per-replica throughputs, which would count the same
    # wall-clock interval once per replica.  NaN endpoints mean the
    # source metrics carried no arrival/done timestamps.
    total_tokens: int = 0
    total_e2e: float = 0.0
    t_min_arrival: float = float("nan")
    t_max_done: float = float("nan")
    # per-metric raw-value reservoir (first RESERVOIR_MAX per-request
    # values, deterministic) enabling exact percentile merges; None on
    # hand-built aggregates and on merges whose union outgrew the bound
    samples: Optional[Dict[str, List[float]]] = None

    def row(self, keys: Iterable[str] = METRIC_KEYS) -> Dict[str, float]:
        """Means per metric key; an empty aggregate yields NaNs (never a
        KeyError — renderers show them as ``-``)."""
        return {k: self.means.get(k, float("nan")) for k in keys}


def aggregate(metrics: List[dict]) -> MetricsAggregate:
    if not metrics:
        return MetricsAggregate(0, {}, {}, {}, 0.0)
    means, p50, p99 = {}, {}, {}
    samples: Dict[str, List[float]] = {}
    for k in METRIC_KEYS:
        vals = np.array([m[k] for m in metrics], dtype=np.float64)
        means[k] = float(vals.mean())
        p50[k] = float(np.percentile(vals, 50))
        p99[k] = float(np.percentile(vals, 99))
        samples[k] = [float(v) for v in vals[:RESERVOIR_MAX]]
    total_tokens = sum(m["prompt_len"] + m["output_len"] for m in metrics)
    total_e2e = sum(m["e2e"] for m in metrics)
    tok_per_req = total_tokens / total_e2e if total_e2e else 0.0
    # wall-clock throughput over the batch's makespan; requests recorded
    # without endpoints (hand-built dicts) fall back to the per-request
    # rate rather than inventing a wall-clock
    t_lo = t_hi = float("nan")
    if all(m.get("arrival") is not None and m.get("done") is not None
           for m in metrics):
        t_lo = min(m["arrival"] for m in metrics)
        t_hi = max(m["done"] for m in metrics)
        makespan = t_hi - t_lo
        throughput = total_tokens / makespan if makespan > 0 \
            else tok_per_req
    else:
        throughput = tok_per_req
    return MetricsAggregate(
        n=len(metrics), means=means, p50=p50, p99=p99,
        throughput_tok_per_s=throughput, tok_per_req_s=tok_per_req,
        total_tokens=total_tokens, total_e2e=total_e2e,
        t_min_arrival=t_lo, t_max_done=t_hi, samples=samples)


def merge_aggregates(parts: List[MetricsAggregate]) -> MetricsAggregate:
    """Merge per-replica aggregates into one fleet aggregate.

    Replicas run CONCURRENTLY, so the fleet's wall-clock throughput is
    the union's Σ tokens over the union's makespan (earliest arrival →
    latest done across every part) — summing or averaging per-replica
    throughputs would count overlapped wall-clock once per replica and
    overstate the fleet rate.  Means merge exactly (n-weighted).
    Percentiles merge EXACTLY from the per-part sample reservoirs
    whenever every part carries a complete one (each part's n ≤
    RESERVOIR_MAX — comfortably true for every run this repo performs);
    only when a part has reduced away its raw values (hand-built
    aggregates, or a part that outgrew its reservoir) does the merge
    fall back to the historical n-weighted mean of per-part
    percentiles, an approximation that is exact only when the parts
    are identically distributed.  The merged aggregate keeps the
    concatenated samples while they still fit the bound, so chained
    merges (fleet-of-fleets) stay exact too.
    """
    parts = [p for p in parts if p.n]
    if not parts:
        return MetricsAggregate(0, {}, {}, {}, 0.0)
    if len(parts) == 1:
        return parts[0]
    n = sum(p.n for p in parts)

    def wmean(dicts: List[Dict[str, float]]) -> Dict[str, float]:
        keys = set().union(*dicts)
        return {k: sum(d.get(k, 0.0) * p.n for d, p in zip(dicts, parts))
                / n for k in keys}

    # Exact percentile path: every part still carries its complete raw
    # values (len == n for every metric key), so the union's
    # percentiles are computed from the concatenation, not
    # approximated.  Any incomplete part downgrades the whole merge.
    exact = all(
        p.samples is not None
        and all(len(p.samples.get(k, ())) == p.n for k in METRIC_KEYS)
        for p in parts)
    p50: Dict[str, float] = {}
    p99: Dict[str, float] = {}
    merged_samples: Optional[Dict[str, List[float]]] = None
    if exact:
        pooled = {k: [v for p in parts for v in p.samples[k]]  # type: ignore[index]
                  for k in METRIC_KEYS}
        for k, vals in pooled.items():
            arr = np.asarray(vals, dtype=np.float64)
            p50[k] = float(np.percentile(arr, 50))
            p99[k] = float(np.percentile(arr, 99))
        if n <= RESERVOIR_MAX:
            merged_samples = pooled
    else:
        p50 = wmean([p.p50 for p in parts])
        p99 = wmean([p.p99 for p in parts])

    total_tokens = sum(p.total_tokens for p in parts)
    total_e2e = sum(p.total_e2e for p in parts)
    tok_per_req = total_tokens / total_e2e if total_e2e else 0.0
    arrivals = [p.t_min_arrival for p in parts]
    dones = [p.t_max_done for p in parts]
    t_lo = t_hi = float("nan")
    if not any(np.isnan(arrivals)) and not any(np.isnan(dones)):
        t_lo, t_hi = min(arrivals), max(dones)
        makespan = t_hi - t_lo
        throughput = total_tokens / makespan if makespan > 0 \
            else tok_per_req
    else:
        throughput = tok_per_req
    return MetricsAggregate(
        n=n,
        means=wmean([p.means for p in parts]),
        p50=p50, p99=p99,
        throughput_tok_per_s=throughput, tok_per_req_s=tok_per_req,
        total_tokens=total_tokens, total_e2e=total_e2e,
        t_min_arrival=t_lo, t_max_done=t_hi, samples=merged_samples)


@dataclass
class AdapterPoolStats:
    """Adapter-lifecycle counters (the Prometheus-gauge equivalents for
    the dynamic adapter pool): how often weights moved, how full the
    slot pool ran, and whether admission ever stalled on weights."""
    num_slots: int = 0
    num_registered: int = 0
    occupancy: int = 0            # resident slots right now
    prefetch_issued: int = 0      # async H2D transfers started
    prefetch_hits: int = 0        # installs that found staged weights
    resident_hits: int = 0        # acquire found the slot warm
    installs: int = 0             # slot writes (scatter into the stack)
    evictions: int = 0            # LRU slot reclaims
    acquire_fails: int = 0        # admissions queued behind eviction
    stalled_installs: int = 0     # installs whose H2D was never prefetched
    staged_now: int = 0           # staging copies on device right now
    staged_dropped: int = 0       # stages expired/unregistered unclaimed
    prefetch_deferred: int = 0    # prefetches refused at the staging budget

    def row(self) -> Dict[str, float]:
        return {k: float(getattr(self, k)) for k in (
            "num_slots", "num_registered", "occupancy", "prefetch_issued",
            "prefetch_hits", "resident_hits", "installs", "evictions",
            "acquire_fails", "stalled_installs", "staged_now",
            "staged_dropped", "prefetch_deferred")}


def speedup_table(baseline: MetricsAggregate, ours: MetricsAggregate,
                  keys: Iterable[str] = ("e2e", "ttft", "queue", "prefill",
                                         "decode")) -> Dict[str, float]:
    """Paper-style speedup factors (baseline=LoRA / ours=aLoRA).

    A stage ABSENT from either side (the aggregate never saw it — empty
    stage, or a hand-built aggregate without the key) yields NaN, which
    ``fmt_speedups`` renders as ``-``.  ``inf`` is reserved for a TRUE
    measured zero in ours against a positive baseline (the stage really
    took no time); a 0/0 stage is a 1.0 no-op, not an infinite speedup.
    The old behaviour collapsed all three cases to ``inf``, which made
    empty baselines look like unbounded wins in the benchmark CSVs.
    """
    out = {}
    for k in keys:
        b = baseline.means.get(k, float("nan"))
        o = ours.means.get(k, float("nan"))
        if math.isnan(b) or math.isnan(o):
            out[k] = float("nan")           # stage absent → render "-"
        elif o == 0.0:
            out[k] = float("inf") if b > 0 else 1.0
        else:
            out[k] = b / o
    return out


def fmt_speedups(sp: Dict[str, float]) -> str:
    """Render a ``speedup_table`` dict for CSV notes / stdout: absent
    stages (NaN) show as ``-`` instead of ``nanx``."""
    return " ".join(
        f"{k}=-" if math.isnan(v) else f"{k}={v:.2f}x"
        for k, v in sp.items())
