"""Request lifecycle (paper Table 2 / Fig. 5).

A request moves through queue → prefill → decode → done; the boundary
timestamps define the paper's metrics:

  queue time   = t_prefill_start - t_arrival
  prefill time = t_decode_start  - t_prefill_start
  decode time  = t_done          - t_decode_start
  TTFT         = queue + prefill
  ITL          = decode / (n_output - 1)
  E2E          = queue + prefill + decode
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.core.alora import AdapterSpec
from repro.core.block_hash import AdapterKey, BlockHash


class State(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclass
class Request:
    req_id: int
    prompt: List[int]                       # token ids used for hashing
    max_new_tokens: int
    adapter: Optional[AdapterSpec] = None
    # stable registry identity (name#vN) — what block hashes salt on.
    # NEVER the slot index: slots are recycled across evictions, and the
    # same name can be re-registered with different weights, so neither
    # is a sound cache key.
    adapter_uid: Optional[str] = None
    adapter_slot: int = 0                   # device slot WHILE ADMITTED
    arrival_time: float = 0.0
    # multimodal stubs -------------------------------------------------------
    prefix_embeds: Optional[np.ndarray] = None   # vlm: (P, d) patch embeds
    frame_embeds: Optional[np.ndarray] = None    # audio: (Se, d) frames
    salt: Tuple = ()                        # cache salt (content digest)
    # lifecycle --------------------------------------------------------------
    state: State = State.QUEUED
    t_prefill_start: Optional[float] = None
    t_decode_start: Optional[float] = None
    t_done: Optional[float] = None
    output_tokens: List[int] = field(default_factory=list)
    # cache bookkeeping --------------------------------------------------------
    inv_start: int = 0                      # activation point (aLoRA)
    # bumped on every preemption: rows of this request riding a
    # submitted-but-unretired async step carry the epoch they were
    # scheduled under, and the retire phase drops rows whose epoch no
    # longer matches (their bookkeeping was rolled back by the preempt)
    epoch: int = 0
    # scans of the affinity admission window in which a YOUNGER request
    # was admitted past this one; at
    # EngineConfig.admission_starvation_cap the request becomes an
    # admission barrier and can never be bypassed again
    admission_skips: int = 0
    block_ids: List[int] = field(default_factory=list)
    hashes: List[BlockHash] = field(default_factory=list)  # full-block chain
    n_computed: int = 0                     # prompt tokens with KV in cache
    n_cache_hit_tokens: int = 0             # reused via prefix cache
    run_slot: int = -1                      # live-state slot (SSM archs)
    state_reused: bool = False
    # runner scratch -----------------------------------------------------------
    input_embeds: Any = None                # (S, d) jax array, grows w/ decode

    # -------------------------------------------------------------------------
    @property
    def seq_len(self) -> int:
        return len(self.prompt) + len(self.output_tokens)

    @property
    def all_tokens(self) -> List[int]:
        return self.prompt + self.output_tokens

    def adapter_key(self) -> Optional[AdapterKey]:
        if self.adapter is None:
            return None
        return AdapterKey(self.adapter_uid or self.adapter.name,
                          self.adapter.kind, self.inv_start)

    def is_finished(self) -> bool:
        return len(self.output_tokens) >= self.max_new_tokens

    # -- metrics --------------------------------------------------------------
    def metrics(self) -> dict:
        assert self.state == State.DONE
        queue = self.t_prefill_start - self.arrival_time
        prefill = self.t_decode_start - self.t_prefill_start
        decode = self.t_done - self.t_decode_start
        n_out = max(len(self.output_tokens), 1)
        return {
            "req_id": self.req_id,
            "queue": queue,
            "prefill": prefill,
            "decode": decode,
            "ttft": queue + prefill,
            "itl": decode / max(n_out - 1, 1),
            "e2e": queue + prefill + decode,
            "inference": prefill + decode,
            # absolute endpoints for makespan-based throughput (metrics
            # aggregation must not double-count overlapped wall-clock)
            "arrival": self.arrival_time,
            "done": self.t_done,
            "prompt_len": len(self.prompt),
            "output_len": len(self.output_tokens),
            "cache_hit_tokens": self.n_cache_hit_tokens,
            "cache_hit_frac": self.n_cache_hit_tokens
            / max(len(self.prompt), 1),
        }
