"""Serving engine: paged KV cache + cross-model prefix reuse + aLoRA."""
from repro.serving.engine import Engine, EngineConfig  # noqa: F401
from repro.serving.metrics import (aggregate, MetricsAggregate,  # noqa: F401
                                   speedup_table)
from repro.serving.request import Request, State  # noqa: F401
from repro.serving.runner import ModelRunner, RunnerConfig  # noqa: F401
