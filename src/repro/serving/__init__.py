"""Serving engine: paged KV cache + cross-model prefix reuse + aLoRA +
dynamic adapter lifecycle (paged adapter-slot pool)."""
from repro.serving.adapter_pool import AdapterPool
from repro.serving.engine import Engine, EngineConfig
from repro.serving.metrics import (AdapterPoolStats, MetricsAggregate,
                                   aggregate, fmt_speedups, speedup_table)
from repro.serving.request import Request, State
from repro.serving.runner import ModelRunner, RunnerConfig

__all__ = [
    "AdapterPool", "AdapterPoolStats", "Engine", "EngineConfig",
    "MetricsAggregate", "ModelRunner", "Request", "RunnerConfig", "State",
    "aggregate", "fmt_speedups", "speedup_table",
]
