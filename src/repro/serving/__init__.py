"""Serving engine: paged KV cache + cross-model prefix reuse + aLoRA +
dynamic adapter lifecycle (paged adapter-slot pool)."""
from repro.serving.adapter_pool import AdapterPool  # noqa: F401
from repro.serving.engine import Engine, EngineConfig  # noqa: F401
from repro.serving.metrics import (AdapterPoolStats,  # noqa: F401
                                   aggregate, MetricsAggregate,
                                   speedup_table)
from repro.serving.request import Request, State  # noqa: F401
from repro.serving.runner import ModelRunner, RunnerConfig  # noqa: F401
