"""The serving engine: scheduler + continuous batching + chunked prefill +
cross-model prefix caching (the paper's system, §3).

Request flow (paper Fig. 5):

  submit → [queue] → admission (prefix-cache match: base-aligned block
  hashes + SSM state snapshots) → chunked prefill (budgeted per step,
  interleaved with decodes) → decode (continuous batching) → done

The engine runs a discrete-event loop with a **virtual clock**: arrivals
follow the benchmark-provided schedule; each ``step()`` executes real
jitted model work and advances the clock by its measured wall time.  This
reproduces queue-buildup dynamics (paper §4.2.1/4.3) honestly on CPU with
reduced-scale models — the code path is identical to a real deployment,
only the device differs.

Cross-model reuse appears in two places:

* admission calls ``PrefixCache.match_and_acquire`` with the request's
  ``AdapterKey`` — aLoRA requests transparently hit blocks prefilled by
  the base model or sibling adapters (and vice versa);
* every block filled — during prefill OR decode (generated tokens are
  cached too, paper §4.4) — is registered under its base-aligned hash.

Adapters are a dynamic, paged resource (``serving/adapter_pool.py``):
the registry can hold far more adapters than fit on device, and the
scheduler is adapter-aware — waiting requests trigger async weight
prefetch, admission pins a device slot (or queues behind eviction), and
finish/preemption unpin it.  Block hashes salt on the registration uid,
so slot recycling never aliases the prefix cache.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.core.activation_mask import (adapter_index_for_positions,
                                        find_invocation_start)
from repro.core.alora import AdapterSpec
from repro.core.block_hash import (block_extra, hash_block,
                                   request_block_hashes)
from repro.core.kv_manager import BlockManager, OutOfBlocks
from repro.core.prefix_cache import PrefixCache
from repro.models.model import Runtime, period_segments
from repro.serving.adapter_pool import (AdapterPool, AdapterRegistration,
                                        rank_bucket)
from repro.serving.metrics import (AdapterPoolStats, MetricsAggregate,
                                   aggregate)
from repro.serving.request import Request, State
from repro.serving.runner import MixedBatch, ModelRunner, RunnerConfig


@dataclass(frozen=True)
class EngineConfig:
    block_size: int = 16
    num_blocks: int = 512
    max_running: int = 8
    num_state_slots: int = 64
    max_batched_tokens: int = 128     # chunked-prefill budget per step
    enable_prefix_cache: bool = True
    # "mixed": one jitted device call per step over a single ragged batch
    # of all decode tokens + prefill chunks (vLLM v1-style) — the default
    # for EVERY architecture family: attention-only, SSM/hybrid (ragged
    # SSD scan with per-token live-state gather/scatter) and
    # encoder-decoder (per-row cross-attention KV).
    # "sequential": the v0-style separate decode_batch/prefill_chunk path,
    # kept as an explicit config choice (equivalence oracle + debugging).
    execution_mode: str = "mixed"
    # attention impl for the mixed step: "ref" (jnp gather, runs
    # everywhere) | "pallas" (TPU kernel) | "pallas_interpret" (tests)
    mixed_attn_impl: str = "ref"
    # ragged-SSD impl for the mixed step, same choices as above
    mixed_ssd_impl: str = "ref"
    # grouped-LoRA delta for the mixed step: "ref" (ragged jnp over the
    # step's active slots) | "pallas"/"pallas_interpret" (SGMV kernel) |
    # "dense" (pre-pool full stacked scan; equivalence oracle)
    mixed_lora_impl: str = "ref"
    # ---- dynamic adapter pool (serving/adapter_pool.py) --------------
    # device-resident adapter slots.  None -> one slot per adapter given
    # at construction (everything resident, the pre-pool behavior);
    # smaller values make admission cycle adapters through the slots
    # (LRU eviction + async prefetch).
    adapter_slots: Optional[int] = None
    # rank bucket every adapter zero-pads into.  None -> pow2 bucket of
    # the largest construction-time adapter rank (min 8).  Must be set
    # explicitly if later registrations need a higher rank.
    adapter_slot_rank: Optional[int] = None
    # execution-time model: clock advances by measured wall time of each
    # step, scaled by this factor (1.0 = honest CPU timing)
    time_scale: float = 1.0
    # ---- TP-sharded execution (distributed/sharding.py §Sharded serving)
    # Shard the one jitted mixed step over this mesh: params tensor-
    # parallel, the paged K/V pool on its KV-head dim, SSM pools on
    # head/channel dims, adapter slot B stacks on their output dim, and
    # per-token scheduler metadata replicated.  The host-side scheduler,
    # block manager and adapter registry stay single-process.  None (the
    # default) keeps the single-device path exactly as before.  Requires
    # execution_mode="mixed" and the jnp "ref" kernel impls (GSPMD
    # partitions them; Pallas kernels are single-device).
    mesh: Optional[jax.sharding.Mesh] = None


class Engine:
    def __init__(self, cfg: ModelConfig, params, *,
                 engine_cfg: EngineConfig = EngineConfig(),
                 adapters: Optional[List[Tuple[AdapterSpec, dict]]] = None,
                 rt: Runtime = Runtime()):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.rt = rt
        if engine_cfg.mesh is not None \
                and engine_cfg.execution_mode != "mixed":
            raise ValueError(
                "sharded execution (EngineConfig.mesh) is built on the "
                "one-call-per-step mixed path; execution_mode="
                f"{engine_cfg.execution_mode!r} is single-device only")
        adapters = adapters or []
        # dynamic adapter pool: construction-time adapters are ordinary
        # registrations; more can be registered/unregistered at any time
        # and cycle through the fixed device slots (heterogeneous ranks
        # zero-pad into the slot bucket — no equal-rank requirement)
        self.adapter_pool: Optional[AdapterPool] = None
        if adapters or engine_cfg.adapter_slots is not None:
            n_slots = engine_cfg.adapter_slots \
                if engine_cfg.adapter_slots is not None \
                else max(len(adapters), 1)
            slot_rank = engine_cfg.adapter_slot_rank \
                if engine_cfg.adapter_slot_rank is not None \
                else rank_bucket(max((s.rank for s, _ in adapters),
                                     default=1))
            self.adapter_pool = AdapterPool(cfg, num_slots=n_slots,
                                            slot_rank=slot_rank,
                                            mesh=engine_cfg.mesh)
            for spec, w in adapters:
                self.adapter_pool.register(spec, w)

        rcfg = RunnerConfig(
            block_size=engine_cfg.block_size,
            num_blocks=engine_cfg.num_blocks + 1,
            max_running=engine_cfg.max_running + 1,
            num_state_slots=engine_cfg.num_state_slots + 1,
            mixed_attn_impl=engine_cfg.mixed_attn_impl,
            mixed_ssd_impl=engine_cfg.mixed_ssd_impl,
            mixed_lora_impl=engine_cfg.mixed_lora_impl,
        )
        self.runner = ModelRunner(
            cfg, params, rcfg,
            self.adapter_pool.layers if self.adapter_pool else None, rt,
            mesh=engine_cfg.mesh)

        has_attn = self.runner.La > 0
        has_ssm = self.runner.Ls > 0
        kv_mgr = BlockManager(engine_cfg.num_blocks,
                              engine_cfg.block_size) if has_attn else None
        st_mgr = BlockManager(engine_cfg.num_state_slots,
                              engine_cfg.block_size) if has_ssm else None
        self.kv_mgr = kv_mgr
        self.st_mgr = st_mgr
        self.cache = PrefixCache(block_size=engine_cfg.block_size,
                                 kv_manager=kv_mgr, state_manager=st_mgr) \
            if engine_cfg.enable_prefix_cache else None

        self.clock = 0.0
        self._next_id = 0
        self.pending: List[Request] = []      # future arrivals (sorted)
        self.waiting: List[Request] = []      # arrived, not yet admitted
        self.running: List[Request] = []      # prefill/decode in flight
        self.done: List[Request] = []
        self._free_slots = list(range(engine_cfg.max_running))
        self._xkv: Dict[int, tuple] = {}      # req_id -> encoder KV
        self._budget_debt = 0                 # min-progress overdraft
        self.preemptions = 0
        self.last_step_tokens = (0, 0)        # (n_decode, n_prefill)
        self.t_assembly = 0.0                 # host-side batch-pack time
        if engine_cfg.execution_mode not in ("mixed", "sequential"):
            raise ValueError(
                f"unknown execution_mode {engine_cfg.execution_mode!r}: "
                "expected 'mixed' or 'sequential'")
        self.use_mixed = engine_cfg.execution_mode == "mixed"

    # ------------------------------------------------------------------
    # adapter lifecycle (delegates to the AdapterPool)
    # ------------------------------------------------------------------
    @property
    def adapters(self) -> Dict[str, AdapterRegistration]:
        """Currently-registered adapters, by name."""
        pool = self.adapter_pool
        if pool is None:
            return {}
        return {name: pool.get(pool.uid_of(name))
                for name in pool.registered}

    def register_adapter(self, spec: AdapterSpec, weights) -> str:
        """Register an adapter at any time; returns its registry uid.
        The engine may hold many more registrations than device slots —
        residency is managed per admission."""
        if self.adapter_pool is None:
            raise RuntimeError(
                "engine was built without an adapter pool; pass "
                "adapters=... at construction or set "
                "EngineConfig.adapter_slots")
        return self.adapter_pool.register(spec, weights)

    def unregister_adapter(self, name: str) -> None:
        """Drop a registration.  Refuses while any live request (queued,
        waiting or running) still references it."""
        if self.adapter_pool is None:
            raise KeyError(name)
        uid = self.adapter_pool.uid_of(name)
        for group in (self.running, self.waiting, self.pending):
            if any(r.adapter_uid == uid for r in group):
                raise RuntimeError(
                    f"adapter {name!r} still referenced by live requests")
        self.adapter_pool.unregister(name)

    def adapter_pool_stats(self) -> AdapterPoolStats:
        if self.adapter_pool is None:
            return AdapterPoolStats()
        return self.adapter_pool.stats()

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int, *,
               adapter_name: Optional[str] = None,
               arrival_time: Optional[float] = None,
               prefix_embeds: Optional[np.ndarray] = None,
               frame_embeds: Optional[np.ndarray] = None,
               salt: Tuple = ()) -> int:
        req = Request(
            req_id=self._next_id,
            prompt=list(map(int, prompt)),
            max_new_tokens=max_new_tokens,
            arrival_time=self.clock if arrival_time is None
            else arrival_time,
            prefix_embeds=prefix_embeds,
            frame_embeds=frame_embeds,
            salt=salt,
        )
        self._next_id += 1
        if adapter_name is not None:
            pool = self.adapter_pool
            if pool is None:
                raise KeyError(adapter_name)
            uid = pool.uid_of(adapter_name)
            ra = pool.get(uid)
            req.adapter = ra.spec
            req.adapter_uid = uid       # stable cache identity; the
            req.adapter_slot = 0        # device slot is pinned at admission
            if ra.spec.kind == "alora":
                inv = find_invocation_start(req.prompt,
                                            ra.spec.invocation_tokens)
                # invocation sequence absent -> activate at end of prompt
                req.inv_start = len(req.prompt) if inv is None else inv
        if req.arrival_time <= self.clock:
            self.waiting.append(req)
        else:
            self.pending.append(req)
            self.pending.sort(key=lambda r: r.arrival_time)
        return req.req_id

    # ------------------------------------------------------------------
    # admission: prefix-cache match + block allocation
    # ------------------------------------------------------------------
    def _try_admit(self, req: Request) -> bool:
        ecfg = self.ecfg
        bs = ecfg.block_size
        n_prompt = len(req.prompt)
        needs_slot = self.runner.Ls > 0
        if needs_slot and not self._free_slots:
            return False

        adapter_pinned = False

        # prefix-cache match.  We match against prompt[:-1]: the last
        # prompt token must always be recomputed to produce first-token
        # logits, so the reuse boundary (KV blocks AND the SSM state
        # snapshot, which must sit at the SAME boundary) never covers it.
        n_reuse, kv_blocks, state_slot = 0, [], None
        req.hashes = request_block_hashes(req.prompt, bs,
                                          req.adapter_key(), req.salt)
        if self.cache is not None:
            m = self.cache.match_and_acquire(req.prompt[:-1],
                                             req.adapter_key(), req.salt)
            n_reuse, kv_blocks, state_slot = (m.n_tokens, m.kv_blocks,
                                              m.state_slot)

        # allocate blocks for the uncached remainder of the prompt
        n_total_blocks = (n_prompt + bs - 1) // bs
        n_new = n_total_blocks - len(kv_blocks)
        new_blocks: List[int] = []

        def bail() -> bool:
            # single cleanup for every failure path: return everything
            # acquired so far — cache-matched blocks, partially
            # allocated fresh blocks, the state-snapshot ref, and the
            # adapter-slot pin (the slot stays resident/warm for retry)
            if self.kv_mgr is not None:
                self.kv_mgr.release_all(kv_blocks + new_blocks)
            if state_slot is not None:
                self.st_mgr.release(state_slot)
            if adapter_pinned:
                self.adapter_pool.release(req.adapter_uid)
                req.adapter_slot = 0
            return False

        mgr = self.kv_mgr
        if mgr is not None:
            if mgr.num_free() < n_new:
                return bail()
            try:
                for _ in range(n_new):
                    new_blocks.append(mgr.allocate())
            except OutOfBlocks:
                return bail()
            req.block_ids = kv_blocks + new_blocks

        # adapter admission charge, AFTER blocks so a block-side failure
        # never pays an eviction+install for nothing: pin the adapter's
        # device slot (installing it, evicting an LRU-unpinned slot if
        # needed).  When every slot is pinned by running requests the
        # admission fails — the request queues behind eviction, never
        # behind a device sync.
        if req.adapter_uid is not None:
            slot = self.adapter_pool.acquire(req.adapter_uid)
            if slot is None:
                req.block_ids = []
                return bail()
            req.adapter_slot = slot
            adapter_pinned = True

        req.n_computed = n_reuse
        req.n_cache_hit_tokens = n_reuse
        if needs_slot:
            req.run_slot = self._free_slots.pop()
            if state_slot is not None:
                self.runner.restore_state(state_slot, req.run_slot)
                req.state_reused = True
                self.st_mgr.release(state_slot)   # copied into live state
            else:
                self.runner.reset_live(req.run_slot)

        # embeddings + (whisper) encoder KV.  Kept host-side (numpy) so
        # the mixed-batch assembly packs rows without device round-trips.
        req.input_embeds = np.asarray(self.runner.build_input_embeds(
            req.prompt, req.prefix_embeds))
        if self.cfg.is_encoder_decoder:
            assert req.frame_embeds is not None
            self._xkv[req.req_id] = self.runner.encode(req.frame_embeds)

        req.state = State.PREFILL
        self.running.append(req)
        return True

    # ------------------------------------------------------------------
    # one scheduler step
    # ------------------------------------------------------------------
    def step(self) -> float:
        """Run one engine iteration; returns the step's execution time."""
        # move due arrivals into the waiting queue
        while self.pending and self.pending[0].arrival_time <= self.clock:
            self.waiting.append(self.pending.pop(0))
        # scheduler-driven adapter prefetch: issue the async host→device
        # transfer for every adapter an admission-window request will
        # need, so the weights are staged (or already in flight) by the
        # time admission pins a slot below
        if self.adapter_pool is not None:
            window = max(self.ecfg.max_running - len(self.running), 0)
            for r in self.waiting[:window]:
                if r.adapter_uid is not None:
                    self.adapter_pool.prefetch(r.adapter_uid)
        # idle: jump to the next arrival
        if not self.waiting and not self.running:
            if self.pending:
                self.clock = self.pending[0].arrival_time
                return 0.0
            return 0.0

        t_before = self.clock
        # decode first: running requests claim their next block BEFORE
        # admission can hand freed blocks to new/preempted requests —
        # this (plus recompute-preemption below) guarantees progress
        # under block starvation (vLLM's decode-priority scheduling)
        decodes = self._schedule_decodes()
        n_decode = len(decodes)

        # admit FCFS while capacity allows
        while self.waiting and len(self.running) < self.ecfg.max_running:
            if not self._try_admit(self.waiting[0]):
                break
            self.waiting.pop(0)

        # chunked-prefill budget: whatever the decodes left of
        # max_batched_tokens, minus last step's minimum-progress
        # overdraft.  Only when NO decode ran may prefill overdraw by one
        # block (minimum progress); the overdraft is charged to the next
        # step instead of silently violating the cap.
        avail = self.ecfg.max_batched_tokens - n_decode - self._budget_debt
        budget = avail
        if n_decode == 0 and budget < self.ecfg.block_size:
            budget = self.ecfg.block_size
        prefills = self._schedule_prefills(budget)
        n_prefill = sum(hi - lo for _, lo, hi in prefills)
        # everything spent this step (decodes are non-deferrable) plus
        # inherited debt beyond the cap carries forward — debt is paid
        # down by under-cap steps, never silently forgiven
        self._budget_debt = max(0, n_decode + n_prefill
                                + self._budget_debt
                                - self.ecfg.max_batched_tokens)
        self.last_step_tokens = (n_decode, n_prefill)

        if self.use_mixed:
            self._execute_mixed(decodes, prefills)
        else:
            self._execute_decodes(decodes)
            self._execute_prefills(prefills)
        self._finish_requests()
        # block starvation with zero progress: preempt the most recent
        # running request (vLLM recompute-preemption) so the others can
        # allocate; it re-enters the queue and re-prefills via the
        # prefix cache
        if n_decode == 0 and n_prefill == 0 and self.running:
            self._preempt(self.running[-1])
        return self.clock - t_before

    # ------------------------------------------------------------------
    def _preempt(self, r: Request) -> None:
        if self.kv_mgr is not None and r.block_ids:
            self.kv_mgr.release_all(r.block_ids)
        r.block_ids = []
        if r.run_slot >= 0:
            self._free_slots.append(r.run_slot)
            r.run_slot = -1
        if r.adapter_uid is not None and r.adapter_slot > 0:
            self.adapter_pool.release(r.adapter_uid)
            r.adapter_slot = 0
        r.n_computed = 0
        r.state_reused = False
        r.state = State.QUEUED
        # drop the encoder KV now: re-admission re-encodes, and a
        # preempted-then-never-readmitted request must not pin its
        # cross-attention tensors for the engine's lifetime
        self._xkv.pop(r.req_id, None)
        self.running.remove(r)
        self.waiting.insert(0, r)
        self.preemptions += 1
        if self.preemptions > 1000:
            raise RuntimeError("preemption livelock: pool too small for "
                               "a single request")

    # ------------------------------------------------------------------
    # scheduling: pick this step's work (and claim blocks) WITHOUT
    # executing — both execution paths consume the same schedule
    # ------------------------------------------------------------------
    def _schedule_decodes(self) -> List[Request]:
        decodes = [r for r in self.running if r.state == State.DECODE]
        bs = self.ecfg.block_size
        # ensure each request has a block for the position it writes
        ok: List[Request] = []
        for r in decodes:
            pos = r.n_computed
            if self.kv_mgr is not None:
                while len(r.block_ids) <= pos // bs:
                    try:
                        r.block_ids.append(self.kv_mgr.allocate())
                    except OutOfBlocks:
                        break
                if len(r.block_ids) <= pos // bs:
                    continue                        # starved; retry later
            ok.append(r)
        return ok

    def _schedule_prefills(self, budget: int
                           ) -> List[Tuple[Request, int, int]]:
        bs = self.ecfg.block_size
        spans: List[Tuple[Request, int, int]] = []
        for r in self.running:
            if budget <= 0:
                break
            if r.state != State.PREFILL:
                continue
            n_prompt = len(r.prompt)
            lo = r.n_computed
            hi = min(n_prompt, lo + min(budget,
                                        self.runner.rcfg.chunk_tokens))
            # keep chunk boundaries block-aligned except the final chunk
            if hi < n_prompt:
                hi = lo + ((hi - lo) // bs) * bs
                if hi <= lo:
                    continue
            if r.t_prefill_start is None:
                r.t_prefill_start = self.clock
            budget -= hi - lo
            spans.append((r, lo, hi))
        return spans

    # ------------------------------------------------------------------
    # post-execution bookkeeping shared by both execution paths
    # ------------------------------------------------------------------
    def _postprocess_decode(self, r: Request, tok: int) -> None:
        r.n_computed += 1
        self._on_block_boundary(r)
        # append only when at the sampling frontier (after a
        # preemption the decode path RECOMPUTES known tokens first)
        if r.n_computed == len(r.all_tokens) and not r.is_finished():
            r.output_tokens.append(tok)

    def _postprocess_prefill(self, r: Request, lo: int, hi: int,
                             logits_row: np.ndarray, boundary) -> None:
        r.n_computed = hi
        # register every block completed by this chunk (+ snapshots)
        self._register_prefill_blocks(r, lo, hi, boundary)
        if hi == len(r.prompt):                     # prefill complete
            r.state = State.DECODE
            if r.t_decode_start is None:
                r.t_decode_start = self.clock
            if not r.output_tokens:                 # not a re-prefill
                r.output_tokens.append(int(np.argmax(logits_row)))

    def _adapter_idx(self, r: Request, positions: np.ndarray) -> np.ndarray:
        return adapter_index_for_positions(
            positions, r.adapter_slot,
            r.adapter.kind if r.adapter else None, r.inv_start)

    # ------------------------------------------------------------------
    # sequential execution (v0-style: one decode batch + one device call
    # per prefill chunk; kept as an explicit execution_mode choice — the
    # mixed path's equivalence oracle and a debugging aid)
    # ------------------------------------------------------------------
    def _execute_decodes(self, ok: List[Request]) -> None:
        if not ok:
            return
        tokens = np.array([r.all_tokens[r.n_computed] for r in ok],
                          np.int32)
        positions = np.array([r.n_computed for r in ok], np.int32)
        lengths = positions + 1
        adapter_idx = np.array([
            self._adapter_idx(r, np.array([r.n_computed]))[0]
            for r in ok], np.int32)
        run_slots = np.array([max(r.run_slot, 0) for r in ok], np.int32)
        block_tables = [r.block_ids for r in ok]
        xkv_list = None
        if self.cfg.is_encoder_decoder:
            xkv_list = [self._xkv[r.req_id] for r in ok]
        t0 = time.perf_counter()
        logits = self.runner.decode_batch(
            tokens=tokens, positions=positions, block_tables=block_tables,
            lengths=lengths, adapter_idx=adapter_idx, run_slots=run_slots,
            xkv_list=xkv_list)
        logits = np.asarray(logits)               # sync
        self.clock += (time.perf_counter() - t0) * self.ecfg.time_scale
        nxt = np.argmax(logits, axis=-1)
        for r, t in zip(ok, nxt):
            self._postprocess_decode(r, int(t))

    def _execute_prefills(self,
                          spans: List[Tuple[Request, int, int]]) -> None:
        for r, lo, hi in spans:
            aidx = self._adapter_idx(r, np.arange(lo, hi))
            t0 = time.perf_counter()
            logits, boundary = self.runner.prefill_chunk(
                input_embeds=r.input_embeds, lo=lo, hi=hi,
                block_ids=r.block_ids if self.kv_mgr is not None else [],
                adapter_idx_row=aidx, run_slot=max(r.run_slot, 0),
                xkv=self._xkv.get(r.req_id))
            logits = np.asarray(logits)           # sync
            self.clock += (time.perf_counter() - t0) * self.ecfg.time_scale
            self._postprocess_prefill(r, lo, hi, logits, boundary)

    # ------------------------------------------------------------------
    # unified mixed-batch execution: ALL decode tokens and prefill chunks
    # of the step packed into one ragged batch → one jitted device call.
    # Serves every architecture family: attention-only, SSM/hybrid
    # (ragged SSD scan over the packed axis) and encoder-decoder
    # (per-row cross-attention KV indexed by req_rows).
    # ------------------------------------------------------------------
    def _execute_mixed(self, decodes: List[Request],
                       prefills: List[Tuple[Request, int, int]]) -> None:
        if not decodes and not prefills:
            return
        t_host = time.perf_counter()
        bs = self.ecfg.block_size
        reqs = decodes + [r for r, _, _ in prefills]
        R = len(reqs)
        T = len(decodes) + sum(hi - lo for _, lo, hi in prefills)

        # host-side assembly into the runner's persistent capacity-
        # doubling buffers (no per-step reallocation)
        take = self.runner.host_bufs.take
        tok_ids = take("e_tok", T, np.int32)
        embeds = take("e_emb", T, np.float32,
                      trailing=(self.cfg.d_model,))
        use_embeds = take("e_use", T, bool)
        positions = take("e_pos", T, np.int32)
        adapter_idx = take("e_ad", T, np.int32)
        req_rows = take("e_rows", T, np.int32)
        row_cols = take("e_cols", T, np.int32)
        write_bids = take("e_wb", T, np.int32)
        write_offs = take("e_wo", T, np.int32)
        out_rows = take("e_out", R, np.int32)
        run_slots = take("e_slots", R, np.int32)
        block_tables = [list(r.block_ids) for r in reqs]
        # packed indices of prefill block-boundary tokens (SSM snapshot
        # emission points) + each span's (offset, count) into that list
        snap_rows: List[int] = []
        span_snaps: List[Tuple[int, int]] = []

        t = 0
        for i, r in enumerate(decodes):
            pos = r.n_computed
            tok_ids[t] = r.all_tokens[pos]
            positions[t] = pos
            adapter_idx[t] = self._adapter_idx(r, np.array([pos]))[0]
            req_rows[t] = i
            if self.kv_mgr is not None:
                write_bids[t] = r.block_ids[pos // bs]
                write_offs[t] = pos % bs
            out_rows[i] = t
            run_slots[i] = max(r.run_slot, 0)
            t += 1
        for j, (r, lo, hi) in enumerate(prefills):
            row = len(decodes) + j
            n = hi - lo
            sl = slice(t, t + n)
            pr = np.arange(lo, hi)
            embeds[sl] = np.asarray(r.input_embeds[lo:hi], np.float32)
            use_embeds[sl] = True
            positions[sl] = pr
            adapter_idx[sl] = self._adapter_idx(r, pr)
            req_rows[sl] = row
            row_cols[sl] = pr - lo
            if self.kv_mgr is not None:
                bids = np.asarray(r.block_ids, np.int32)
                write_bids[sl] = bids[pr // bs]
                write_offs[sl] = pr % bs
            out_rows[row] = t + n - 1
            run_slots[row] = max(r.run_slot, 0)
            off = len(snap_rows)
            if self.st_mgr is not None:
                # every b in range(lo//bs, hi//bs) is a FULL block:
                # (b+1)*bs <= hi by construction
                for b in range(lo // bs, hi // bs):
                    snap_rows.append(t + (b + 1) * bs - 1 - lo)
            span_snaps.append((off, len(snap_rows) - off))
            t += n

        xkv_list = None
        if self.cfg.is_encoder_decoder:
            xkv_list = [(r.req_id, self._xkv[r.req_id]) for r in reqs]

        # the step's active adapter slots (ascending, for the grouped-
        # LoRA delta): every token's adapter_idx is either 0 or its
        # request's pinned slot, so the per-request set covers the batch
        active = sorted({r.adapter_slot for r in reqs
                         if r.adapter_slot > 0})

        mb = MixedBatch(tok_ids=tok_ids, embeds=embeds,
                        use_embeds=use_embeds, positions=positions,
                        adapter_idx=adapter_idx, req_rows=req_rows,
                        row_cols=row_cols, write_bids=write_bids,
                        write_offs=write_offs, block_tables=block_tables,
                        out_rows=out_rows, run_slots=run_slots,
                        snap_rows=np.asarray(snap_rows, np.int32),
                        xkv_list=xkv_list,
                        active_slots=np.asarray(active, np.int32))
        self.t_assembly += time.perf_counter() - t_host
        t0 = time.perf_counter()
        logits, boundary = self.runner.execute_batch(mb)  # one jitted call
        self.clock += (time.perf_counter() - t0) * self.ecfg.time_scale
        # decode bookkeeping first, then prefill — the same order the
        # sequential path registers blocks in
        for i, r in enumerate(decodes):
            self._postprocess_decode(r, int(np.argmax(logits[i])))
        for j, (r, lo, hi) in enumerate(prefills):
            bnd = None
            if boundary is not None:
                off, cnt = span_snaps[j]
                bnd = (boundary[0][:, off:off + cnt],
                       boundary[1][:, off:off + cnt])
            self._postprocess_prefill(r, lo, hi, logits[len(decodes) + j],
                                      bnd)

    # ------------------------------------------------------------------
    def _adopt_canonical(self, r: Request, b: int, h) -> None:
        """Register block ``b`` of ``r`` under hash ``h``.  When another
        live block already owns the hash (concurrent identical prefixes),
        remap the request onto the canonical block and release the
        duplicate back to the pool instead of keeping both allocated."""
        bid = r.block_ids[b]
        canon = self.cache.register_kv_block(h, bid)
        if canon != bid:
            self.kv_mgr.acquire(canon)
            self.kv_mgr.release(bid)
            r.block_ids[b] = canon

    # ------------------------------------------------------------------
    def _register_prefill_blocks(self, r: Request, lo: int, hi: int,
                                 boundary) -> None:
        if self.cache is None:
            return
        bs = self.ecfg.block_size
        for b in range(lo // bs, hi // bs):
            if (b + 1) * bs > hi:
                break
            h = r.hashes[b]
            if self.kv_mgr is not None and b < len(r.block_ids):
                self._adopt_canonical(r, b, h)
            if self.st_mgr is not None:
                # boundary states are per chunk of size bs within [lo, hi)
                c_idx = b - lo // bs
                if self.st_mgr.lookup(h) is None:
                    try:
                        slot = self.st_mgr.allocate()
                    except OutOfBlocks:
                        continue
                    self.runner.snapshot_boundary(boundary, c_idx, slot)
                    self.cache.register_state(h, slot)
                    self.st_mgr.release(slot)       # cached, not owned

    # ------------------------------------------------------------------
    def _on_block_boundary(self, r: Request) -> None:
        """After computing token at position n_computed-1 during decode:
        if it completed a block, hash + register it (generated tokens are
        cached too — paper §4.4)."""
        if self.cache is None:
            return
        bs = self.ecfg.block_size
        pos = r.n_computed
        if pos % bs != 0:
            return
        b = pos // bs - 1
        toks = r.all_tokens
        # extend the hash chain INCREMENTALLY from the last cached parent
        # (one hash_block per new block; recomputing the whole chain from
        # token 0 made long decodes O(n²) in hashing work)
        while len(r.hashes) <= b:
            i = len(r.hashes)
            lo, hi = i * bs, (i + 1) * bs
            parent = r.hashes[-1] if r.hashes else None
            extra = r.salt + block_extra(r.adapter_key(), lo, hi)
            r.hashes.append(hash_block(parent, toks[lo:hi], extra))
        h = r.hashes[b]
        if self.kv_mgr is not None and b < len(r.block_ids):
            self._adopt_canonical(r, b, h)
        if self.st_mgr is not None and self.st_mgr.lookup(h) is None:
            try:
                slot = self.st_mgr.allocate()
            except OutOfBlocks:
                return
            self.runner.snapshot_live(max(r.run_slot, 0), slot)
            self.cache.register_state(h, slot)
            self.st_mgr.release(slot)

    # ------------------------------------------------------------------
    def _finish_requests(self) -> None:
        still = []
        for r in self.running:
            if r.state == State.DECODE and r.is_finished():
                r.state = State.DONE
                r.t_done = self.clock
                if self.kv_mgr is not None:
                    self.kv_mgr.release_all(r.block_ids)
                if r.run_slot >= 0:
                    self._free_slots.append(r.run_slot)
                if r.adapter_uid is not None and r.adapter_slot > 0:
                    self.adapter_pool.release(r.adapter_uid)
                    r.adapter_slot = 0
                self._xkv.pop(r.req_id, None)
                self.done.append(r)
            else:
                still.append(r)
        self.running = still

    # ------------------------------------------------------------------
    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not (self.pending or self.waiting or self.running):
                return
            self.step()
        raise RuntimeError("engine did not drain")

    # ------------------------------------------------------------------
    def metrics_for(self, req_ids: Sequence[int]) -> MetricsAggregate:
        ids = set(req_ids)
        return aggregate([r.metrics() for r in self.done
                          if r.req_id in ids])

    def request(self, req_id: int) -> Request:
        for pool in (self.done, self.running, self.waiting, self.pending):
            for r in pool:
                if r.req_id == req_id:
                    return r
        raise KeyError(req_id)

    def kv_hit_rate(self) -> float:
        mgr = self.kv_mgr or self.st_mgr
        return mgr.hit_rate()
