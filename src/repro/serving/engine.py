"""The serving engine: scheduler + continuous batching + chunked prefill +
cross-model prefix caching (the paper's system, §3).

Request flow (paper Fig. 5):

  submit → [queue] → admission (prefix-cache match: base-aligned block
  hashes + SSM state snapshots) → chunked prefill (budgeted per step,
  interleaved with decodes) → decode (continuous batching) → done

The engine runs a discrete-event loop with a **virtual clock**: arrivals
follow the benchmark-provided schedule; each ``step()`` executes real
jitted model work and advances the clock by its measured wall time.  This
reproduces queue-buildup dynamics (paper §4.2.1/4.3) honestly on CPU with
reduced-scale models — the code path is identical to a real deployment,
only the device differs.

Cross-model reuse appears in two places:

* admission calls ``PrefixCache.match_and_acquire`` with the request's
  ``AdapterKey`` — aLoRA requests transparently hit blocks prefilled by
  the base model or sibling adapters (and vice versa);
* every block filled — during prefill OR decode (generated tokens are
  cached too, paper §4.4) — is registered under its base-aligned hash.

Adapters are a dynamic, paged resource (``serving/adapter_pool.py``):
the registry can hold far more adapters than fit on device, and the
scheduler is adapter-aware — waiting requests trigger async weight
prefetch, admission pins a device slot (or queues behind eviction), and
finish/preemption unpin it.  Block hashes salt on the registration uid,
so slot recycling never aliases the prefix cache.

Each iteration is an explicit **schedule → submit → retire** pipeline
(see ``Engine.step``): sampling runs on device inside the mixed step,
so with ``EngineConfig.async_submission`` (the default) step N+1 is
scheduled, assembled and dispatched BEFORE step N's sampled token ids
are synced to host — all host-side work hides under device compute, and
the per-step device→host payload is a handful of int32 ids instead of
``(R, vocab)`` logits.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from itertools import islice
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.activation_mask import adapter_index_for_positions, find_invocation_start
from repro.core.alora import AdapterSpec
from repro.core.block_hash import (AdapterKey, block_extra, hash_block,
                                   request_block_hashes)
from repro.core.kv_manager import BlockManager, OutOfBlocks
from repro.core.prefix_cache import PrefixCache
from repro.models.model import Runtime
from repro.obs.tracer import Tracer
from repro.serving.adapter_pool import AdapterPool, AdapterRegistration, rank_bucket
from repro.serving.metrics import AdapterPoolStats, MetricsAggregate, aggregate
from repro.serving.request import Request, State
from repro.serving.runner import MixedBatch, ModelRunner, RunnerConfig, StepHandle

# placeholder a submitted-but-unretired step leaves in output_tokens:
# the token's VALUE is still on device (patched at retire); its position
# already counts for scheduling.  Never a valid vocab id.
PENDING = -1


@dataclass
class _InflightStep:
    """A submitted mixed step awaiting retirement: the device handle
    plus, per request row, the bookkeeping that must wait for the
    sampled token ids — ``(request, epoch-at-submit, sampled-row index,
    output_tokens patch index | None, decode block-boundary position |
    None, eagerly-claimed state-snapshot slot | None)``."""
    handle: StepHandle
    retires: List[Tuple[Request, int, int, Optional[int], Optional[int],
                        Optional[int]]]


@dataclass(frozen=True)
class EngineConfig:
    block_size: int = 16
    num_blocks: int = 512
    max_running: int = 8
    num_state_slots: int = 64
    max_batched_tokens: int = 128     # chunked-prefill budget per step
    enable_prefix_cache: bool = True
    # "mixed": one jitted device call per step over a single ragged batch
    # of all decode tokens + prefill chunks (vLLM v1-style) — the default
    # for EVERY architecture family: attention-only, SSM/hybrid (ragged
    # SSD scan with per-token live-state gather/scatter) and
    # encoder-decoder (per-row cross-attention KV).
    # "sequential": the v0-style separate decode_batch/prefill_chunk path,
    # kept as an explicit config choice (equivalence oracle + debugging).
    execution_mode: str = "mixed"
    # attention impl for the mixed step: "ref" (jnp gather, runs
    # everywhere) | "pallas" (TPU kernel) | "pallas_interpret" (tests)
    mixed_attn_impl: str = "ref"
    # ragged-SSD impl for the mixed step, same choices as above
    mixed_ssd_impl: str = "ref"
    # grouped-LoRA delta for the mixed step: "ref" (ragged jnp over the
    # step's active slots) | "pallas"/"pallas_interpret" (SGMV kernel) |
    # "dense" (pre-pool full stacked scan; equivalence oracle)
    mixed_lora_impl: str = "ref"
    # ---- dynamic adapter pool (serving/adapter_pool.py) --------------
    # device-resident adapter slots.  None -> one slot per adapter given
    # at construction (everything resident, the pre-pool behavior);
    # smaller values make admission cycle adapters through the slots
    # (LRU eviction + async prefetch).
    adapter_slots: Optional[int] = None
    # rank bucket every adapter zero-pads into.  None -> pow2 bucket of
    # the largest construction-time adapter rank (min 8).  Must be set
    # explicitly if later registrations need a higher rank.
    adapter_slot_rank: Optional[int] = None
    # ---- adapter-aware admission (docs/scheduling.md) ----------------
    # "affinity" (default): scan a bounded window of the waiting queue,
    # skip requests blocked on slots/blocks, and admit base-model /
    # resident-adapter / staged-adapter requests first (same-adapter
    # admissions batched), under the starvation-age cap below.  "fcfs":
    # strict queue order with head-of-line break — the equivalence
    # oracle (and the pre-scheduler behaviour).  Admission order never
    # changes any request's tokens (greedy decoding is per-request
    # deterministic; the mixed≡sequential suites prove batch-composition
    # independence) — only queueing latency.
    admission_policy: str = "affinity"
    # how deep into `waiting` the affinity scan and the prefetch pass
    # look each step
    admission_window: int = 32
    # starvation-age cap K: once a scanned-but-bypassed request has been
    # overtaken by younger admissions in K scans, it becomes a barrier —
    # nothing behind it in the queue admits before it does
    admission_starvation_cap: int = 8
    # ---- adapter staging tier (AdapterPool) --------------------------
    # max registrations holding a device staging copy at once (prefetch
    # past it is deferred, not dropped).  None -> one per adapter slot.
    adapter_staging_budget: Optional[int] = None
    # scheduler ticks until a staged-but-never-claimed copy expires —
    # the bound on the prefetch-leak window
    adapter_staging_ttl: int = 64
    # slot eviction-policy hook forwarded to AdapterPool: given the
    # unpinned resident uids (least-recently-acquired first), returns
    # the victim uid.  None = LRU.
    adapter_evict_policy: Optional[Callable[[Sequence[str]], str]] = None
    # ---- async step pipeline (schedule → submit → retire) ------------
    # True (default): one-step-lookahead submission.  Sampling runs on
    # device inside the mixed step, only the (R,) int32 sampled ids ever
    # cross to host, and step N's host sync happens AFTER step N+1 has
    # been scheduled, assembled and dispatched — host work overlaps
    # device compute.  False retires every step before the next one is
    # scheduled: the synchronous oracle the async path must match
    # token for token.  Mixed-mode only; "sequential" execution is
    # always synchronous.
    async_submission: bool = True
    # execution-time model: clock advances by measured wall time of each
    # step, scaled by this factor (1.0 = honest CPU timing)
    time_scale: float = 1.0
    # ---- TP-sharded execution (distributed/sharding.py §Sharded serving)
    # Shard the one jitted mixed step over this mesh: params tensor-
    # parallel, the paged K/V pool on its KV-head dim, SSM pools on
    # head/channel dims, adapter slot B stacks on their output dim, and
    # per-token scheduler metadata replicated.  The host-side scheduler,
    # block manager and adapter registry stay single-process.  None (the
    # default) keeps the single-device path exactly as before.  Requires
    # execution_mode="mixed" and the jnp "ref" kernel impls (GSPMD
    # partitions them; Pallas kernels are single-device).
    mesh: Optional[jax.sharding.Mesh] = None
    # With a mesh whose "data" axis has size > 1, additionally shard the
    # PACKED TOKEN AXIS of the mixed step over that axis: per-token
    # metadata rows and input embeds split across the data devices, so
    # max_batched_tokens scales with the data-axis size instead of every
    # device redundantly computing the full packed batch.  Per-request
    # arrays and the sampled ids stay replicated (retirement and the next
    # step's from_buf gathers read them whole).  False keeps the
    # replicate-everything TP layout (the sharded≡unsharded A/B leg).
    data_shard_tokens: bool = True
    # ---- tracing (repro.obs) -----------------------------------------
    # Record request-lifecycle spans, step-phase spans, the cache-reuse
    # ledger and pool events into this engine's Tracer.  None (default)
    # follows the environment: on unless REPRO_TRACE=0.  Recording is
    # append-only plain python (hot-path safe, lint-enforced); the
    # overhead budget is bench-asserted (<2% mean step latency,
    # benchmarks/bench_mixed_batch.py --trace-check).
    trace: Optional[bool] = None


class Engine:
    def __init__(self, cfg: ModelConfig, params, *,
                 engine_cfg: EngineConfig = EngineConfig(),
                 adapters: Optional[List[Tuple[AdapterSpec, dict]]] = None,
                 rt: Runtime = Runtime()):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.rt = rt
        if engine_cfg.mesh is not None \
                and engine_cfg.execution_mode != "mixed":
            raise ValueError(
                "sharded execution (EngineConfig.mesh) is built on the "
                "one-call-per-step mixed path; execution_mode="
                f"{engine_cfg.execution_mode!r} is single-device only")
        adapters = adapters or []
        # trace recorder (repro.obs): created FIRST so the adapter pool
        # and runner can stamp events into the same per-replica rings;
        # the router re-stamps replica ids after construction
        self.tracer = Tracer(enabled=engine_cfg.trace)
        # dynamic adapter pool: construction-time adapters are ordinary
        # registrations; more can be registered/unregistered at any time
        # and cycle through the fixed device slots (heterogeneous ranks
        # zero-pad into the slot bucket — no equal-rank requirement)
        self.adapter_pool: Optional[AdapterPool] = None
        if adapters or engine_cfg.adapter_slots is not None:
            n_slots = engine_cfg.adapter_slots \
                if engine_cfg.adapter_slots is not None \
                else max(len(adapters), 1)
            slot_rank = engine_cfg.adapter_slot_rank \
                if engine_cfg.adapter_slot_rank is not None \
                else rank_bucket(max((s.rank for s, _ in adapters),
                                     default=1))
            self.adapter_pool = AdapterPool(
                cfg, num_slots=n_slots, slot_rank=slot_rank,
                mesh=engine_cfg.mesh, tracer=self.tracer,
                staging_budget=engine_cfg.adapter_staging_budget,
                staging_ttl=engine_cfg.adapter_staging_ttl,
                evict_policy=engine_cfg.adapter_evict_policy)
            for spec, w in adapters:
                self.adapter_pool.register(spec, w)

        rcfg = RunnerConfig(
            block_size=engine_cfg.block_size,
            num_blocks=engine_cfg.num_blocks + 1,
            max_running=engine_cfg.max_running + 1,
            num_state_slots=engine_cfg.num_state_slots + 1,
            mixed_attn_impl=engine_cfg.mixed_attn_impl,
            mixed_ssd_impl=engine_cfg.mixed_ssd_impl,
            mixed_lora_impl=engine_cfg.mixed_lora_impl,
            data_shard_tokens=engine_cfg.data_shard_tokens,
        )
        self.runner = ModelRunner(
            cfg, params, rcfg,
            self.adapter_pool.layers if self.adapter_pool else None, rt,
            mesh=engine_cfg.mesh, tracer=self.tracer)

        has_attn = self.runner.La > 0
        has_ssm = self.runner.Ls > 0
        kv_mgr = BlockManager(engine_cfg.num_blocks,
                              engine_cfg.block_size) if has_attn else None
        st_mgr = BlockManager(engine_cfg.num_state_slots,
                              engine_cfg.block_size) if has_ssm else None
        self.kv_mgr = kv_mgr
        self.st_mgr = st_mgr
        self.cache = PrefixCache(block_size=engine_cfg.block_size,
                                 kv_manager=kv_mgr, state_manager=st_mgr) \
            if engine_cfg.enable_prefix_cache else None

        self.clock = 0.0
        self._next_id = 0
        # deques: arrivals pop from the left every step and preemption
        # pushes to the front — with the admission-window scan these
        # queues are hot at depth, and list.pop(0) is O(n)
        self.pending: "deque[Request]" = deque()   # future arrivals (sorted)
        self.waiting: "deque[Request]" = deque()   # arrived, not admitted
        self.running: List[Request] = []      # prefill/decode in flight
        self.done: List[Request] = []
        self._free_slots = list(range(engine_cfg.max_running))
        self._xkv: Dict[int, tuple] = {}      # req_id -> encoder KV
        self._budget_debt = 0                 # min-progress overdraft
        self.preemptions = 0
        self.last_step_tokens = (0, 0)        # (n_decode, n_prefill)
        self.t_assembly = 0.0                 # host-side batch-pack time
        if engine_cfg.execution_mode not in ("mixed", "sequential"):
            raise ValueError(
                f"unknown execution_mode {engine_cfg.execution_mode!r}: "
                "expected 'mixed' or 'sequential'")
        if engine_cfg.admission_policy not in ("affinity", "fcfs"):
            raise ValueError(
                f"unknown admission_policy "
                f"{engine_cfg.admission_policy!r}: "
                "expected 'affinity' or 'fcfs'")
        if engine_cfg.admission_window < 1 \
                or engine_cfg.admission_starvation_cap < 1:
            raise ValueError("admission_window and "
                             "admission_starvation_cap must be >= 1")
        self.use_mixed = engine_cfg.execution_mode == "mixed"
        self.use_async = self.use_mixed and engine_cfg.async_submission
        self._inflight: Optional[_InflightStep] = None
        # steps whose schedule/assembly ran while the previous step was
        # still executing on device (the overlap the pipeline exists for)
        self.async_overlap_steps = 0

    # ------------------------------------------------------------------
    # adapter lifecycle (delegates to the AdapterPool)
    # ------------------------------------------------------------------
    @property
    def adapters(self) -> Dict[str, AdapterRegistration]:
        """Currently-registered adapters, by name."""
        pool = self.adapter_pool
        if pool is None:
            return {}
        return {name: pool.get(pool.uid_of(name))
                for name in pool.registered}

    def register_adapter(self, spec: AdapterSpec, weights) -> str:
        """Register an adapter at any time; returns its registry uid.
        The engine may hold many more registrations than device slots —
        residency is managed per admission."""
        if self.adapter_pool is None:
            raise RuntimeError(
                "engine was built without an adapter pool; pass "
                "adapters=... at construction or set "
                "EngineConfig.adapter_slots")
        return self.adapter_pool.register(spec, weights)

    def unregister_adapter(self, name: str) -> None:
        """Drop a registration.  Refuses while any live request (queued,
        waiting or running) still references it."""
        if self.adapter_pool is None:
            raise KeyError(name)
        uid = self.adapter_pool.uid_of(name)
        for group in (self.running, self.waiting, self.pending):
            if any(r.adapter_uid == uid for r in group):
                raise RuntimeError(
                    f"adapter {name!r} still referenced by live requests")
        self.adapter_pool.unregister(name)

    def adapter_pool_stats(self) -> AdapterPoolStats:
        if self.adapter_pool is None:
            return AdapterPoolStats()
        return self.adapter_pool.stats()

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int, *,
               adapter_name: Optional[str] = None,
               arrival_time: Optional[float] = None,
               prefix_embeds: Optional[np.ndarray] = None,
               frame_embeds: Optional[np.ndarray] = None,
               salt: Tuple = ()) -> int:
        req = Request(
            req_id=self._next_id,
            prompt=list(map(int, prompt)),
            max_new_tokens=max_new_tokens,
            arrival_time=self.clock if arrival_time is None
            else arrival_time,
            prefix_embeds=prefix_embeds,
            frame_embeds=frame_embeds,
            salt=salt,
        )
        self._next_id += 1
        if adapter_name is not None:
            pool = self.adapter_pool
            if pool is None:
                raise KeyError(adapter_name)
            uid = pool.uid_of(adapter_name)
            ra = pool.get(uid)
            req.adapter = ra.spec
            req.adapter_uid = uid       # stable cache identity; the
            req.adapter_slot = 0        # device slot is pinned at admission
            if ra.spec.kind == "alora":
                inv = find_invocation_start(req.prompt,
                                            ra.spec.invocation_tokens)
                # invocation sequence absent -> activate at end of prompt
                req.inv_start = len(req.prompt) if inv is None else inv
        if req.arrival_time <= self.clock:
            self.waiting.append(req)
        else:
            self.pending.append(req)
            if len(self.pending) > 1 \
                    and req.arrival_time < self.pending[-2].arrival_time:
                self.pending = deque(sorted(
                    self.pending, key=lambda r: r.arrival_time))
        if self.tracer.enabled:
            self.tracer.event("lifecycle", "arrival", req.arrival_time,
                              {"req_id": req.req_id,
                               "prompt_len": len(req.prompt),
                               "adapter_uid": req.adapter_uid})
        return req.req_id

    # ------------------------------------------------------------------
    # admission: prefix-cache match + block allocation
    # ------------------------------------------------------------------
    def _try_admit(self, req: Request) -> bool:
        ecfg = self.ecfg
        bs = ecfg.block_size
        n_prompt = len(req.prompt)
        # every request pins a run slot: SSM archs keep live state there,
        # and ALL archs address the runner's per-slot last-sampled-token
        # buffer through it (async decode rows read the previous token on
        # device, so the slot is the token's stable identity)
        if not self._free_slots:
            return False

        adapter_pinned = False

        # prefix-cache match.  We match against prompt[:-1]: the last
        # prompt token must always be recomputed to produce first-token
        # logits, so the reuse boundary (KV blocks AND the SSM state
        # snapshot, which must sit at the SAME boundary) never covers it.
        n_reuse, kv_blocks, state_slot = 0, [], None
        req.hashes = request_block_hashes(req.prompt, bs,
                                          req.adapter_key(), req.salt)
        if self.cache is not None:
            m = self.cache.match_and_acquire(req.prompt[:-1],
                                             req.adapter_key(), req.salt)
            n_reuse, kv_blocks, state_slot = (m.n_tokens, m.kv_blocks,
                                              m.state_slot)

        # allocate blocks for the uncached remainder of the prompt
        n_total_blocks = (n_prompt + bs - 1) // bs
        n_new = n_total_blocks - len(kv_blocks)
        new_blocks: List[int] = []

        def bail() -> bool:
            # single cleanup for every failure path: return everything
            # acquired so far — cache-matched blocks, partially
            # allocated fresh blocks, the state-snapshot ref, and the
            # adapter-slot pin (the slot stays resident/warm for retry)
            if self.kv_mgr is not None:
                self.kv_mgr.release_all(kv_blocks + new_blocks)
            if state_slot is not None:
                self.st_mgr.release(state_slot)
            if adapter_pinned:
                self.adapter_pool.release(req.adapter_uid)
                req.adapter_slot = 0
            return False

        mgr = self.kv_mgr
        if mgr is not None:
            if mgr.num_free() < n_new:
                return bail()
            try:
                for _ in range(n_new):
                    new_blocks.append(mgr.allocate())
            except OutOfBlocks:
                return bail()
            req.block_ids = kv_blocks + new_blocks

        # adapter admission charge, AFTER blocks so a block-side failure
        # never pays an eviction+install for nothing: pin the adapter's
        # device slot (installing it, evicting an LRU-unpinned slot if
        # needed).  When every slot is pinned by running requests the
        # admission fails — the request queues behind eviction, never
        # behind a device sync.
        if req.adapter_uid is not None:
            slot = self.adapter_pool.acquire(req.adapter_uid)
            if slot is None:
                req.block_ids = []
                return bail()
            req.adapter_slot = slot
            adapter_pinned = True

        req.n_computed = n_reuse
        req.n_cache_hit_tokens = n_reuse
        req.run_slot = self._free_slots.pop()
        if self.runner.Ls > 0:
            if state_slot is not None:
                self.runner.restore_state(state_slot, req.run_slot)
                req.state_reused = True
                self.st_mgr.release(state_slot)   # copied into live state
            else:
                self.runner.reset_live(req.run_slot)

        # cache-reuse ledger: one row per SUCCESSFUL admission (the
        # aLoRA switch boundary) — tokens the cache served vs the
        # remainder prefill recomputes, under the adapter the request
        # runs as.  Bail paths above returned their blocks and record
        # nothing.
        if self.tracer.enabled:
            self.tracer.ledger_entry(req.req_id, req.adapter_uid, n_reuse,
                                     n_prompt - n_reuse, req.state_reused,
                                     self.clock)

        # embeddings + (whisper) encoder KV.  Kept host-side (numpy) so
        # the mixed-batch assembly packs rows without device round-trips
        # (the one admission-time sync happens inside build_input_embeds,
        # annotated and logged there).
        req.input_embeds = self.runner.build_input_embeds(
            req.prompt, req.prefix_embeds)
        if self.cfg.is_encoder_decoder:
            assert req.frame_embeds is not None
            self._xkv[req.req_id] = self.runner.encode(req.frame_embeds)

        req.state = State.PREFILL
        self.running.append(req)
        return True

    # ------------------------------------------------------------------
    # adapter-aware admission (EngineConfig.admission_policy="affinity")
    # ------------------------------------------------------------------
    def _affinity_class(self, r: Request) -> int:
        """Admission-readiness class: 2 = no install needed (base-model
        request, or adapter already resident in a slot), 1 = weights
        staged on device (install is a local scatter), 0 = host-only
        (install stalls on the H2D copy)."""
        if r.adapter_uid is None:
            return 2
        return self.adapter_pool.affinity_of(r.adapter_uid)

    def _admit_affinity(self) -> None:
        """Windowed adapter-affinity admission (docs/scheduling.md).

        Strict FCFS breaks on the first inadmissible request, so a head
        blocked on a pinned adapter slot starves everything behind it —
        including base-model requests and requests whose adapter is
        already resident.  This scan looks at the first
        ``admission_window`` waiting requests, tries them in affinity
        order (no-install first, staged next, host-only last; equal
        classes keep queue order, same-adapter requests adjacent so
        their admissions batch), and skips — rather than breaks on —
        any that fail on slots/blocks.

        Starvation-age cap: a scanned request bypassed by a younger
        admission bumps ``admission_skips``; once that reaches
        ``admission_starvation_cap`` the request is a *barrier* — the
        candidate set is truncated at the oldest capped request, so
        nothing behind it in the queue can be admitted before it.  The
        capped request's counter can then never advance again: the cap
        is the exact bound on how often any request is bypassed.
        Admission order never alters decoded tokens (greedy decoding is
        per-request deterministic; batch-composition independence is
        proven by the mixed≡sequential suites) — only queue latency.
        """
        ecfg = self.ecfg
        if not self.waiting or len(self.running) >= ecfg.max_running:
            return
        window = list(islice(self.waiting, ecfg.admission_window))
        barrier = len(window) - 1
        for i, r in enumerate(window):
            if r.admission_skips >= ecfg.admission_starvation_cap:
                barrier = i
                break
        candidates = window[:barrier + 1]
        # affinity class desc; within a class, group by adapter uid
        # (base model first) then queue order — stable and deterministic
        order = sorted(
            range(len(candidates)),
            key=lambda i: (-self._affinity_class(candidates[i]),
                           candidates[i].adapter_uid or "", i))
        admitted: List[int] = []
        for i in order:
            if len(self.running) >= ecfg.max_running:
                break
            r = candidates[i]
            # a candidate that needs a slot install is skipped outright
            # while no slot is free or evictable — unlike the FCFS
            # oracle, the scan never issues an acquire it can already
            # see failing (this is most of the acquire_fails win)
            if r.adapter_uid is not None and self._affinity_class(r) < 2 \
                    and not self.adapter_pool.can_take_slot():
                continue
            if self._try_admit(r):
                admitted.append(i)
        if not admitted:
            return                # nothing admitted -> nobody bypassed
        admitted_ids = {id(candidates[i]) for i in admitted}
        # a request is bypassed when a YOUNGER (deeper-queued) request
        # admitted this scan; an older one admitting does not count
        youngest = max(admitted)
        n_skips = 0
        for i, r in enumerate(candidates):
            if i < youngest and id(r) not in admitted_ids:
                r.admission_skips += 1
                n_skips += 1
        # (admissions_total itself is stamped per ledger row in
        # _try_admit — only the skip accounting is scan-level)
        if self.tracer.enabled and n_skips:
            self.tracer.count("admission_skips_total", n_skips)
        self.waiting = deque(r for r in self.waiting
                             if id(r) not in admitted_ids)

    # ------------------------------------------------------------------
    # one scheduler step
    # ------------------------------------------------------------------
    def step(self) -> float:
        """Run one engine iteration; returns the step's execution time.

        The iteration is three explicit phases.  With
        ``async_submission=True`` (default) they form a one-step-
        lookahead pipeline; with ``False`` every step retires before the
        next is scheduled — the synchronous oracle::

                      ┌─ schedule ─┐┌─ submit ──┐┌──── retire ─────┐
            host,     │ decodes,   ││ assemble  ││ sync step N-1's │
            step N    │ admission, ││ batch,    ││ sampled ids,    │
                      │ prefills   ││ dispatch  ││ patch tokens,   │
                      └────────────┘└───────────┘│ hash/register   │
                                                 │ blocks, finish  │
                                                 └─────────────────┘
            device    ──[ step N-1 executing ]───[ step N ]─────────

        Schedule and submit of step N never wait for step N-1's tokens:
        the mixed step samples on device, decode rows read the previous
        token straight from the device ``tok_buf`` (``from_buf``), and
        host bookkeeping that needs the values (``PENDING`` placeholder
        patching, decode block-boundary hashing, request finishing) is
        deferred to the retire phase — which runs AFTER step N is
        already in flight, so the only blocking device→host transfer
        per iteration is the previous step's (R,) int32 sampled array.
        """
        # move due arrivals into the waiting queue
        while self.pending and self.pending[0].arrival_time <= self.clock:
            self.waiting.append(self.pending.popleft())
        # scheduler-driven adapter prefetch: issue the async host→device
        # transfer for every adapter an admission-window request will
        # need, so the weights are staged (or already in flight) by the
        # time admission pins a slot below.  The window is the admission
        # window, NOT spare running capacity: a full engine is exactly
        # when slots are about to free, and prefetching for the queue
        # head there is the whole point of the queue-time head start
        # (the old `max_running - len(running)` window collapsed to zero
        # under load).  Device cost is bounded by the pool's staging
        # budget, not the window; tick() first so expired stages free
        # budget for this step's prefetches.
        if self.adapter_pool is not None:
            self.adapter_pool.tick()
            for r in islice(self.waiting, self.ecfg.admission_window):
                if r.adapter_uid is not None:
                    self.adapter_pool.prefetch(r.adapter_uid)
        # idle: jump to the next arrival
        if not self.waiting and not self.running:
            if self.pending:
                self.clock = self.pending[0].arrival_time
                return 0.0
            return 0.0

        t_before = self.clock
        prev = self._inflight
        self._inflight = None
        tr = self.tracer
        t_sched0 = time.perf_counter()

        # ---- schedule ------------------------------------------------
        # decode first: running requests claim their next block BEFORE
        # admission can hand freed blocks to new/preempted requests —
        # this (plus recompute-preemption below) guarantees progress
        # under block starvation (vLLM's decode-priority scheduling)
        decodes = self._schedule_decodes()
        n_decode = len(decodes)

        # admission: adapter-aware windowed scan (default) or the strict
        # FCFS-with-break oracle (EngineConfig.admission_policy="fcfs")
        if self.ecfg.admission_policy == "fcfs":
            while self.waiting \
                    and len(self.running) < self.ecfg.max_running:
                if not self._try_admit(self.waiting[0]):
                    break
                self.waiting.popleft()
        else:
            self._admit_affinity()

        # chunked-prefill budget: whatever the decodes left of
        # max_batched_tokens, minus last step's minimum-progress
        # overdraft.  Only when NO decode ran may prefill overdraw by one
        # block (minimum progress); the overdraft is charged to the next
        # step instead of silently violating the cap.
        avail = self.ecfg.max_batched_tokens - n_decode - self._budget_debt
        budget = avail
        if n_decode == 0 and budget < self.ecfg.block_size:
            budget = self.ecfg.block_size
        prefills = self._schedule_prefills(budget)
        n_prefill = sum(hi - lo for _, lo, hi in prefills)
        # everything spent this step (decodes are non-deferrable) plus
        # inherited debt beyond the cap carries forward — debt is paid
        # down by under-cap steps, never silently forgiven
        self._budget_debt = max(0, n_decode + n_prefill
                                + self._budget_debt
                                - self.ecfg.max_batched_tokens)
        self.last_step_tokens = (n_decode, n_prefill)
        if tr.enabled:
            tr.span("schedule", "schedule", t_sched0,
                    time.perf_counter(), self.clock,
                    {"n_decode": n_decode, "n_prefill": n_prefill,
                     "running": len(self.running),
                     "waiting": len(self.waiting)})
            tr.count("steps_total")
            tr.count("decode_tokens_total", n_decode)
            tr.count("prefill_tokens_total", n_prefill)

        # ---- submit --------------------------------------------------
        if self.use_mixed:
            t_sub0 = time.perf_counter()
            asm0 = self.t_assembly + self.runner.t_assembly
            inflight = self._submit_mixed(decodes, prefills)
            if tr.enabled and inflight is not None:
                # covers host-side batch assembly (HostBufferPool take +
                # pack, runner _dev_meta staging) AND the jitted dispatch
                tr.span("submit", "submit", t_sub0, time.perf_counter(),
                        self.clock,
                        {"n_decode": n_decode, "n_prefill": n_prefill,
                         "t_assembly": self.t_assembly
                         + self.runner.t_assembly - asm0})
            if inflight is not None and prev is not None:
                self.async_overlap_steps += 1
            if not self.use_async and inflight is not None:
                # synchronous oracle: retire the step we just submitted
                self._retire_traced(inflight)
                inflight = None
            # ---- retire (async: AFTER step N+1 is in flight) --------
            self._retire_traced(prev)
            self._inflight = inflight
        else:
            self._execute_decodes(decodes)
            self._execute_prefills(prefills)
            # sequential oracle: the step above ran to completion, so
            # every token value is already host-known
            # phase: retire-ok (sequential oracle path)
            self._finish_requests()
        # block starvation with zero progress: preempt the most recent
        # running request (vLLM recompute-preemption) so the others can
        # allocate; it re-enters the queue and re-prefills via the
        # prefix cache.  In async mode a just-retired step may have
        # freed blocks/slots — only preempt once the pipeline is fully
        # drained (prev is None) and the scheduler STILL found no work,
        # so preemption never races an in-flight step.
        if n_decode == 0 and n_prefill == 0 and prev is None \
                and self.running:
            # drain-guarded: prev is None means no unretired step is in
            # flight, so no PENDING value can race the rollback
            # phase: retire-ok (pipeline drained)
            self._preempt(self.running[-1])
        return self.clock - t_before

    # ------------------------------------------------------------------
    def _preempt(self, r: Request) -> None:
        # step() itself only preempts with the pipeline fully drained
        # (no unretired step), but _preempt is also callable out of band
        # (tests, future scheduler policies) while rows of r still ride
        # an unretired step: bumping the epoch makes the retire phase
        # drop those rows (their schedule-time bookkeeping is rolled
        # back right here)
        r.epoch += 1
        # drop trailing PENDING placeholders — their producing step will
        # never patch them (epoch mismatch), and recompute-after-
        # readmission must only ever replay host-known token values
        while r.output_tokens and r.output_tokens[-1] == PENDING:
            r.output_tokens.pop()
        if self.kv_mgr is not None and r.block_ids:
            self.kv_mgr.release_all(r.block_ids)
        r.block_ids = []
        if r.run_slot >= 0:
            self._free_slots.append(r.run_slot)
            r.run_slot = -1
        if r.adapter_uid is not None and r.adapter_slot > 0:
            self.adapter_pool.release(r.adapter_uid)
            r.adapter_slot = 0
        r.n_computed = 0
        r.state_reused = False
        r.state = State.QUEUED
        # drop the encoder KV now: re-admission re-encodes, and a
        # preempted-then-never-readmitted request must not pin its
        # cross-attention tensors for the engine's lifetime
        self._xkv.pop(r.req_id, None)
        self.running.remove(r)
        self.waiting.appendleft(r)
        self.preemptions += 1
        if self.tracer.enabled:
            self.tracer.event("schedule", "preempt", self.clock,
                              {"req_id": r.req_id})
            self.tracer.count("preemptions_total")
        if self.preemptions > 1000:
            raise RuntimeError("preemption livelock: pool too small for "
                               "a single request")

    # ------------------------------------------------------------------
    # scheduling: pick this step's work (and claim blocks) WITHOUT
    # executing — both execution paths consume the same schedule
    # ------------------------------------------------------------------
    def _schedule_decodes(self) -> List[Request]:
        # finished-pending requests (async: final token still riding an
        # unretired step) never take another decode row; in sync modes
        # finish always runs before the next schedule, so this filter is
        # a no-op there
        decodes = [r for r in self.running
                   if r.state == State.DECODE and not r.is_finished()]
        bs = self.ecfg.block_size
        # ensure each request has a block for the position it writes
        ok: List[Request] = []
        for r in decodes:
            pos = r.n_computed
            if self.kv_mgr is not None:
                n_before = len(r.block_ids)
                while len(r.block_ids) <= pos // bs:
                    try:
                        r.block_ids.append(self.kv_mgr.allocate())
                    except OutOfBlocks:
                        break
                if len(r.block_ids) <= pos // bs:
                    # starved: return the partial speculative claim — a
                    # skipped request must not sit on blocks it cannot
                    # use this step while admission and the other
                    # decodes starve behind it (needless recompute-
                    # preemptions otherwise); it retries next step
                    while len(r.block_ids) > n_before:
                        self.kv_mgr.release(r.block_ids.pop())
                    continue
            ok.append(r)
        return ok

    def _schedule_prefills(self, budget: int
                           ) -> List[Tuple[Request, int, int]]:
        bs = self.ecfg.block_size
        spans: List[Tuple[Request, int, int]] = []
        for r in self.running:
            if budget <= 0:
                break
            if r.state != State.PREFILL:
                continue
            n_prompt = len(r.prompt)
            lo = r.n_computed
            hi = min(n_prompt, lo + min(budget,
                                        self.runner.rcfg.chunk_tokens))
            # keep chunk boundaries block-aligned except the final chunk
            if hi < n_prompt:
                hi = lo + ((hi - lo) // bs) * bs
                if hi <= lo:
                    continue
            if r.t_prefill_start is None:
                r.t_prefill_start = self.clock
            budget -= hi - lo
            spans.append((r, lo, hi))
        return spans

    # ------------------------------------------------------------------
    # post-execution bookkeeping shared by both execution paths, split
    # into the token-value-free half (``_advance_*`` — runs at submit
    # time, BEFORE the step's sampled ids exist on host) and the
    # deferred half that patches values / hashes generated blocks once
    # the retire phase has synced them
    # ------------------------------------------------------------------
    def _advance_decode(self, r: Request
                        ) -> Tuple[Optional[int], Optional[int],
                                   Optional[int]]:
        """Advance ``r`` past one decode token whose value may still be
        on device.  Returns ``(patch_idx, boundary_pos, snap_slot)`` for
        the retire phase: the output_tokens index holding a PENDING
        placeholder (frontier rows only), the position that completed a
        block (hash + register deferred until its tokens are host-known)
        and the state-snapshot slot claimed for it — snapshotting the
        live SSM state must happen NOW, while the pools still hold this
        step's output (the next submit advances them)."""
        r.n_computed += 1
        bs = self.ecfg.block_size
        pos = r.n_computed
        boundary_pos = snap_slot = None
        if self.cache is not None and pos % bs == 0:
            boundary_pos = pos
            if self.st_mgr is not None:
                b = pos // bs - 1
                # when every token of block b is already host-known (the
                # sync paths always; async only for replayed boundaries
                # — recompute after preemption), the hash is computable
                # NOW: skip the slot claim + device copies for a state
                # the cache already holds, exactly like the pre-split
                # lookup-first path.  Otherwise (async frontier: the fed
                # token may still be PENDING) snapshot speculatively and
                # let the retire phase register or drop it.
                toks = r.all_tokens
                known = all(t != PENDING
                            for t in toks[len(r.hashes) * bs:pos])
                cached = False
                if known and not self.use_async:
                    # sync only: retire follows immediately, so a lookup
                    # hit here is exactly the pre-split lookup-first
                    # behavior.  Async must NOT take the shortcut — the
                    # cached entry could be evicted before this step
                    # retires, and by then the live pools have advanced
                    # past the state, so the speculative snapshot is the
                    # only way to re-register it.
                    # guarded by `known and not use_async`: every token
                    # through block b is host-known on this branch
                    # phase: retire-ok (sync path, tokens host-known)
                    self._extend_hash_chain(r, b)
                    cached = self.st_mgr.lookup(r.hashes[b]) is not None
                if not cached:
                    try:
                        snap_slot = self.st_mgr.allocate()
                    except OutOfBlocks:
                        snap_slot = None  # pool pressure: skip snapshot
                    else:
                        self.runner.snapshot_live(max(r.run_slot, 0),
                                                  snap_slot)
        patch_idx = None
        # extend only at the sampling frontier (after a preemption the
        # decode path RECOMPUTES known tokens first)
        if pos == len(r.all_tokens) and not r.is_finished():
            patch_idx = len(r.output_tokens)
            r.output_tokens.append(PENDING)
        return patch_idx, boundary_pos, snap_slot

    def _advance_prefill(self, r: Request, lo: int, hi: int,
                         boundary) -> Optional[int]:
        """Token-value-free half of prefill postprocessing: block/state
        registration only needs the PROMPT hashes (known at admission),
        so it runs at submit time.  Returns the output_tokens index of
        the first-token PENDING placeholder, or None."""
        r.n_computed = hi
        # register every block completed by this chunk (+ snapshots)
        self._register_prefill_blocks(r, lo, hi, boundary)
        patch_idx = None
        if hi == len(r.prompt):                     # prefill complete
            r.state = State.DECODE
            # t_decode_start is stamped when the first token's VALUE
            # arrives (retire / sync postprocess), not here at submit —
            # TTFT must include the prefill step's device time
            if not r.output_tokens:                 # not a re-prefill
                patch_idx = 0
                r.output_tokens.append(PENDING)
        return patch_idx

    def _postprocess_decode(self, r: Request, tok: int) -> None:
        """Synchronous decode postprocessing (sequential oracle path):
        advance + retire back to back with the host-known token."""
        patch_idx, boundary_pos, snap_slot = self._advance_decode(r)
        if patch_idx is not None:
            r.output_tokens[patch_idx] = tok
        if boundary_pos is not None:
            self._register_decode_block(r, boundary_pos, snap_slot)

    def _postprocess_prefill(self, r: Request, lo: int, hi: int,
                             logits_row: np.ndarray, boundary) -> None:
        patch_idx = self._advance_prefill(r, lo, hi, boundary)
        if r.state == State.DECODE and r.t_decode_start is None:
            r.t_decode_start = self.clock
        if patch_idx is not None:
            r.output_tokens[patch_idx] = int(np.argmax(logits_row))

    def _adapter_idx(self, r: Request, positions: np.ndarray) -> np.ndarray:
        return adapter_index_for_positions(
            positions, r.adapter_slot,
            r.adapter.kind if r.adapter else None, r.inv_start)

    # ------------------------------------------------------------------
    # sequential execution (v0-style: one decode batch + one device call
    # per prefill chunk; kept as an explicit execution_mode choice — the
    # mixed path's equivalence oracle and a debugging aid)
    # ------------------------------------------------------------------
    def _execute_decodes(self, ok: List[Request]) -> None:
        if not ok:
            return
        tokens = np.array([r.all_tokens[r.n_computed] for r in ok],
                          np.int32)
        positions = np.array([r.n_computed for r in ok], np.int32)
        lengths = positions + 1
        adapter_idx = np.array([
            self._adapter_idx(r, np.array([r.n_computed]))[0]
            for r in ok], np.int32)
        run_slots = np.array([max(r.run_slot, 0) for r in ok], np.int32)
        block_tables = [r.block_ids for r in ok]
        xkv_list = None
        if self.cfg.is_encoder_decoder:
            xkv_list = [self._xkv[r.req_id] for r in ok]
        t0 = time.perf_counter()
        logits = self.runner.decode_batch(
            tokens=tokens, positions=positions, block_tables=block_tables,
            lengths=lengths, adapter_idx=adapter_idx, run_slots=run_slots,
            xkv_list=xkv_list)
        logits = np.asarray(logits)               # sync
        self.clock += (time.perf_counter() - t0) * self.ecfg.time_scale
        nxt = np.argmax(logits, axis=-1)
        for r, t in zip(ok, nxt):
            self._postprocess_decode(r, int(t))

    def _execute_prefills(self,
                          spans: List[Tuple[Request, int, int]]) -> None:
        for r, lo, hi in spans:
            aidx = self._adapter_idx(r, np.arange(lo, hi))
            t0 = time.perf_counter()
            logits, boundary = self.runner.prefill_chunk(
                input_embeds=r.input_embeds, lo=lo, hi=hi,
                block_ids=r.block_ids if self.kv_mgr is not None else [],
                adapter_idx_row=aidx, run_slot=max(r.run_slot, 0),
                xkv=self._xkv.get(r.req_id))
            logits = np.asarray(logits)           # sync
            self.clock += (time.perf_counter() - t0) * self.ecfg.time_scale
            self._postprocess_prefill(r, lo, hi, logits, boundary)

    # ------------------------------------------------------------------
    # unified mixed-batch execution: ALL decode tokens and prefill chunks
    # of the step packed into one ragged batch → one jitted device call.
    # Serves every architecture family: attention-only, SSM/hybrid
    # (ragged SSD scan over the packed axis) and encoder-decoder
    # (per-row cross-attention KV indexed by req_rows).  ``_submit_mixed``
    # only DISPATCHES the call and applies the token-value-free
    # bookkeeping; ``_retire`` later syncs the step's sampled ids and
    # applies everything that needed them.
    # ------------------------------------------------------------------
    def _submit_mixed(self, decodes: List[Request],
                      prefills: List[Tuple[Request, int, int]]
                      ) -> Optional[_InflightStep]:
        if not decodes and not prefills:
            return None
        t_host = time.perf_counter()
        bs = self.ecfg.block_size
        reqs = decodes + [r for r, _, _ in prefills]
        R = len(reqs)
        T = len(decodes) + sum(hi - lo for _, lo, hi in prefills)

        # host-side assembly into the runner's persistent capacity-
        # doubling buffers (no per-step reallocation)
        take = self.runner.host_bufs.take
        tok_ids = take("e_tok", T, np.int32)
        embeds = take("e_emb", T, np.float32,
                      trailing=(self.cfg.d_model,))
        use_embeds = take("e_use", T, bool)
        from_buf = take("e_fb", T, bool)
        positions = take("e_pos", T, np.int32)
        adapter_idx = take("e_ad", T, np.int32)
        req_rows = take("e_rows", T, np.int32)
        row_cols = take("e_cols", T, np.int32)
        write_bids = take("e_wb", T, np.int32)
        write_offs = take("e_wo", T, np.int32)
        out_rows = take("e_out", R, np.int32)
        run_slots = take("e_slots", R, np.int32)
        block_tables = [list(r.block_ids) for r in reqs]
        # packed indices of prefill block-boundary tokens (SSM snapshot
        # emission points) + each span's (offset, count) into that list
        snap_rows: List[int] = []
        span_snaps: List[Tuple[int, int]] = []

        t = 0
        for i, r in enumerate(decodes):
            pos = r.n_computed
            tok = r.all_tokens[pos]
            # PENDING: the token is last step's sample, not yet on host —
            # the device reads it from tok_buf at this request's run slot
            from_buf[t] = tok == PENDING
            tok_ids[t] = max(tok, 0)
            positions[t] = pos
            adapter_idx[t] = self._adapter_idx(r, np.array([pos]))[0]
            req_rows[t] = i
            if self.kv_mgr is not None:
                write_bids[t] = r.block_ids[pos // bs]
                write_offs[t] = pos % bs
            out_rows[i] = t
            run_slots[i] = max(r.run_slot, 0)
            t += 1
        for j, (r, lo, hi) in enumerate(prefills):
            row = len(decodes) + j
            n = hi - lo
            sl = slice(t, t + n)
            pr = np.arange(lo, hi)
            embeds[sl] = r.input_embeds[lo:hi]
            use_embeds[sl] = True
            positions[sl] = pr
            adapter_idx[sl] = self._adapter_idx(r, pr)
            req_rows[sl] = row
            row_cols[sl] = pr - lo
            if self.kv_mgr is not None:
                bids = np.array(r.block_ids, np.int32)
                write_bids[sl] = bids[pr // bs]
                write_offs[sl] = pr % bs
            out_rows[row] = t + n - 1
            run_slots[row] = max(r.run_slot, 0)
            off = len(snap_rows)
            if self.st_mgr is not None:
                # every b in range(lo//bs, hi//bs) is a FULL block:
                # (b+1)*bs <= hi by construction
                for b in range(lo // bs, hi // bs):
                    snap_rows.append(t + (b + 1) * bs - 1 - lo)
            span_snaps.append((off, len(snap_rows) - off))
            t += n

        xkv_list = None
        if self.cfg.is_encoder_decoder:
            xkv_list = [(r.req_id, self._xkv[r.req_id]) for r in reqs]

        # the step's active adapter slots (ascending, for the grouped-
        # LoRA delta): every token's adapter_idx is either 0 or its
        # request's pinned slot, so the per-request set covers the batch
        active = sorted({r.adapter_slot for r in reqs
                         if r.adapter_slot > 0})

        mb = MixedBatch(tok_ids=tok_ids, embeds=embeds,
                        use_embeds=use_embeds, from_buf=from_buf,
                        positions=positions,
                        adapter_idx=adapter_idx, req_rows=req_rows,
                        row_cols=row_cols, write_bids=write_bids,
                        write_offs=write_offs, block_tables=block_tables,
                        out_rows=out_rows, run_slots=run_slots,
                        snap_rows=np.array(snap_rows, np.int32),
                        xkv_list=xkv_list,
                        active_slots=np.array(active, np.int32))
        self.t_assembly += time.perf_counter() - t_host
        t0 = time.perf_counter()
        handle = self.runner.submit_batch(mb)   # one jitted call, no sync
        self.clock += (time.perf_counter() - t0) * self.ecfg.time_scale
        # eager (token-value-free) bookkeeping; the retire list records
        # what must wait for the sampled ids.  Decode rows first, then
        # prefill — the same order the sequential path registers blocks
        retires: List[Tuple] = []
        for i, r in enumerate(decodes):
            patch_idx, bpos, slot = self._advance_decode(r)
            retires.append((r, r.epoch, i, patch_idx, bpos, slot))
        for j, (r, lo, hi) in enumerate(prefills):
            bnd = None
            if handle.boundary is not None:
                off, cnt = span_snaps[j]
                bnd = (handle.boundary[0][:, off:off + cnt],
                       handle.boundary[1][:, off:off + cnt])
            patch_idx = self._advance_prefill(r, lo, hi, bnd)
            retires.append((r, r.epoch, len(decodes) + j, patch_idx,
                            None, None))
        return _InflightStep(handle=handle, retires=retires)

    # ------------------------------------------------------------------
    def _retire_traced(self, inf: Optional[_InflightStep]) -> None:
        """``_retire`` wrapped in the retire-phase trace span (covers
        the one sanctioned D2H sync + the deferred bookkeeping)."""
        if inf is None:
            return
        t0 = time.perf_counter()
        self._retire(inf)
        if self.tracer.enabled:
            self.tracer.span("retire", "retire", t0, time.perf_counter(),
                             self.clock, {"rows": len(inf.retires)})

    # ------------------------------------------------------------------
    def _retire(self, inf: Optional[_InflightStep]) -> None:
        """Retire a submitted step: the one blocking device→host sync
        per iteration (the (R,) int32 sampled ids), then the deferred
        bookkeeping — patch PENDING tokens, hash + register decode-
        completed blocks, finish requests.  Rows whose request was
        preempted after submit (epoch mismatch) are dropped; only their
        state-snapshot claim needs returning."""
        if inf is None:
            return
        t0 = time.perf_counter()
        sampled = self.runner.fetch_sampled(inf.handle)
        self.clock += (time.perf_counter() - t0) * self.ecfg.time_scale
        for r, epoch, row, patch_idx, bpos, slot in inf.retires:
            if r.epoch != epoch:
                if slot is not None:
                    self.st_mgr.release(slot)
                continue
            # first-token arrival defines decode start: the clock above
            # just absorbed this step's device time, so TTFT/prefill
            # keep including the prefill step's execution (stamping at
            # submit would shift it into the decode stage)
            if r.state == State.DECODE and r.t_decode_start is None:
                r.t_decode_start = self.clock
            if patch_idx is not None:
                r.output_tokens[patch_idx] = int(sampled[row])
            if bpos is not None:
                self._register_decode_block(r, bpos, slot)
        self._finish_requests()

    # ------------------------------------------------------------------
    def _adopt_canonical(self, r: Request, b: int, h) -> None:
        """Register block ``b`` of ``r`` under hash ``h``.  When another
        live block already owns the hash (concurrent identical prefixes),
        remap the request onto the canonical block and release the
        duplicate back to the pool instead of keeping both allocated."""
        bid = r.block_ids[b]
        canon = self.cache.register_kv_block(h, bid)
        if canon != bid:
            self.kv_mgr.acquire(canon)
            self.kv_mgr.release(bid)
            r.block_ids[b] = canon

    # ------------------------------------------------------------------
    def _register_prefill_blocks(self, r: Request, lo: int, hi: int,
                                 boundary) -> None:
        if self.cache is None:
            return
        bs = self.ecfg.block_size
        for b in range(lo // bs, hi // bs):
            if (b + 1) * bs > hi:
                break
            h = r.hashes[b]
            if self.kv_mgr is not None and b < len(r.block_ids):
                self._adopt_canonical(r, b, h)
            if self.st_mgr is not None:
                # boundary states are per chunk of size bs within [lo, hi)
                c_idx = b - lo // bs
                if self.st_mgr.lookup(h) is None:
                    try:
                        slot = self.st_mgr.allocate()
                    except OutOfBlocks:
                        continue
                    self.runner.snapshot_boundary(boundary, c_idx, slot)
                    self.cache.register_state(h, slot)
                    self.st_mgr.release(slot)       # cached, not owned

    # ------------------------------------------------------------------
    def _extend_hash_chain(self, r: Request, b: int) -> None:
        """Extend the block-hash chain INCREMENTALLY from the last
        cached parent through block ``b`` (one hash_block per new block;
        recomputing the whole chain from token 0 made long decodes O(n²)
        in hashing work).  Idempotent; every token through block ``b``
        must be host-known."""
        bs = self.ecfg.block_size
        toks = r.all_tokens
        while len(r.hashes) <= b:
            i = len(r.hashes)
            lo, hi = i * bs, (i + 1) * bs
            parent = r.hashes[-1] if r.hashes else None
            extra = r.salt + block_extra(r.adapter_key(), lo, hi)
            r.hashes.append(hash_block(parent, toks[lo:hi], extra))

    # ------------------------------------------------------------------
    def _register_decode_block(self, r: Request, pos: int,
                               snap_slot: Optional[int]) -> None:
        """A decode step that reached ``pos`` completed a block: hash +
        register it (generated tokens are cached too — paper §4.4).
        Runs at RETIRE time — the block's token values must be host-
        known; ``snap_slot`` holds the live-state snapshot
        ``_advance_decode`` took while the pools still held that step's
        output."""
        b = pos // self.ecfg.block_size - 1
        self._extend_hash_chain(r, b)
        h = r.hashes[b]
        if self.kv_mgr is not None and b < len(r.block_ids):
            self._adopt_canonical(r, b, h)
        if snap_slot is not None:
            if self.st_mgr.lookup(h) is None:
                self.cache.register_state(h, snap_slot)
            self.st_mgr.release(snap_slot)

    # ------------------------------------------------------------------
    def _finish_requests(self) -> None:
        still = []
        for r in self.running:
            # a request only finishes once its final token VALUE is on
            # host (async: the last output may still be a PENDING
            # placeholder riding the just-submitted step — it finishes
            # at that step's retire, right after the patch)
            if r.state == State.DECODE and r.is_finished() \
                    and (not r.output_tokens
                         or r.output_tokens[-1] != PENDING):
                r.state = State.DONE
                r.t_done = self.clock
                if self.tracer.enabled:
                    self.tracer.request_summary(
                        r.req_id, r.adapter_uid, r.arrival_time,
                        r.t_prefill_start, r.t_decode_start, r.t_done,
                        len(r.prompt), len(r.output_tokens),
                        r.n_cache_hit_tokens)
                if self.kv_mgr is not None:
                    self.kv_mgr.release_all(r.block_ids)
                if r.run_slot >= 0:
                    self._free_slots.append(r.run_slot)
                if r.adapter_uid is not None and r.adapter_slot > 0:
                    self.adapter_pool.release(r.adapter_uid)
                    r.adapter_slot = 0
                self._xkv.pop(r.req_id, None)
                self.done.append(r)
            else:
                still.append(r)
        self.running = still

    # ------------------------------------------------------------------
    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not (self.pending or self.waiting or self.running):
                return
            self.step()
        raise RuntimeError("engine did not drain")

    # ------------------------------------------------------------------
    def metrics_for(self, req_ids: Sequence[int]) -> MetricsAggregate:
        ids = set(req_ids)
        return aggregate([r.metrics() for r in self.done
                          if r.req_id in ids])

    def request(self, req_id: int) -> Request:
        for pool in (self.done, self.running, self.waiting, self.pending):
            for r in pool:
                if r.req_id == req_id:
                    return r
        raise KeyError(req_id)

    def kv_hit_rate(self) -> float:
        mgr = self.kv_mgr or self.st_mgr
        return mgr.hit_rate()

    # ------------------------------------------------------------------
    # replica surface (serving/router.py): read-only placement probes a
    # multi-replica router scores admissions with.  All host-side python
    # over scheduler state — no device work, no cache/statistics mutation.
    # ------------------------------------------------------------------
    @property
    def idle(self) -> bool:
        """No live work anywhere in the pipeline (queued or admitted)."""
        return not (self.pending or self.waiting or self.running)

    def cached_prefix_tokens(self, prompt: Sequence[int],
                             adapter_name: Optional[str] = None,
                             salt: Tuple = ()) -> int:
        """How many leading prompt tokens THIS replica's prefix cache
        could serve, were the request admitted here — the same chained
        base-aligned block hashes admission matches on (so aLoRA probes
        transparently score blocks prefilled by the base model or sibling
        adapters), walked with non-acquiring lookups: refcounts and the
        hit/miss counters are untouched.
        """
        if self.cache is None:
            return 0
        prompt = list(map(int, prompt))
        key: Optional[AdapterKey] = None
        if adapter_name is not None:
            pool = self.adapter_pool
            if pool is None:
                raise KeyError(adapter_name)
            uid = pool.uid_of(adapter_name)
            spec = pool.get(uid).spec
            inv = 0
            if spec.kind == "alora":
                i = find_invocation_start(prompt, spec.invocation_tokens)
                inv = len(prompt) if i is None else i
            key = AdapterKey(uid, spec.kind, inv)
        # match boundary mirrors admission: the last prompt token is
        # always recomputed, so it can never be part of the reuse prefix
        return self.cache.probe(prompt[:-1], key, salt)

    def outstanding_tokens(self) -> int:
        """Remaining work on this replica, in tokens: uncomputed prompt
        plus ungenerated output over every queued + admitted request.
        The router's least-loaded tiebreak."""
        n = 0
        for r in self.pending:
            n += len(r.prompt) + r.max_new_tokens
        for r in self.waiting:
            n += len(r.prompt) + r.max_new_tokens
        for r in self.running:
            n += max(len(r.prompt) - r.n_computed, 0)
            n += max(r.max_new_tokens - len(r.output_tokens), 0)
        return n

    def adapter_residency(self) -> Dict[str, bool]:
        """Adapter name → device-resident (slot installed) snapshot."""
        pool = self.adapter_pool
        return {} if pool is None else pool.residency()

    def adapter_affinity(self, name: str) -> int:
        """Adapter-affinity class of ``name`` on this replica: 2 slot-
        resident (admission is a pin), 1 staged (weights on device
        awaiting install), 0 host-only or unknown.  The graded version
        of :meth:`adapter_residency` the router scores placements with —
        a replica that already staged the weights beats one that must
        start the H2D copy from scratch."""
        pool = self.adapter_pool
        return 0 if pool is None else pool.affinity(name)
