"""Fused aLoRA QKV projection — Pallas TPU kernel.

The paper's hot-path modification (Alg. 1) adds, on top of every QKV
projection, an activation-aware masked low-rank update.  Done naively
that is 1 big matmul + per-adapter (mask → matmul → matmul) passes over
HBM.  This kernel fuses everything into one pass:

  out[t] = x[t] @ W + (x[t] @ A[idx_t]) @ B[idx_t]

TPU mapping: grid over (token tiles, output tiles); each program keeps
its x-tile (Tt × d) resident in VMEM and runs the base matmul on the MXU
followed by the (tiny, rank-r) adapter matmuls — the adapter weights for
ALL stacked adapters fit VMEM because r ≤ 64, so the masked delta costs
no extra HBM traffic for x.  Tile sizes default to MXU-aligned 256×256.

Adapter index 0 is the zero adapter (base-model tokens and pre-activation
tokens of an aLoRA request — the mask of paper Alg. 1); the kernel skips
it by construction since the static loop starts at 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _alora_qkv_kernel(idx_ref, x_ref, w_ref, a_ref, b_ref, o_ref, *,
                      n_adapters: int):
    x = x_ref[...]                                     # (Tt, d)
    acc = jnp.dot(x, w_ref[...],
                  preferred_element_type=jnp.float32)  # (Tt, Ot) on MXU
    idx = idx_ref[...]                                 # (Tt,)
    for i in range(1, n_adapters):                     # static unroll
        sel = (idx == i)
        xm = jnp.where(sel[:, None], x, jnp.zeros_like(x))
        xa = jnp.dot(xm, a_ref[i],
                     preferred_element_type=jnp.float32)   # (Tt, r)
        acc = acc + jnp.dot(xa.astype(x.dtype), b_ref[i],
                            preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def alora_qkv(x: jax.Array, w: jax.Array, a_stack: jax.Array,
              b_stack: jax.Array, adapter_idx: jax.Array, *,
              t_block: int = 256, o_block: int = 256,
              interpret: bool = False) -> jax.Array:
    """x: (T, d); w: (d, out); a_stack: (n, d, r); b_stack: (n, r, out);
    adapter_idx: (T,) int32.  T % t_block == 0 and out % o_block == 0
    (use ``repro.kernels.ops.alora_qkv_op`` for auto-padding)."""
    T, d = x.shape
    out = w.shape[1]
    n, _, r = a_stack.shape
    assert T % t_block == 0 and out % o_block == 0, (T, out)
    grid = (T // t_block, out // o_block)

    kernel = functools.partial(_alora_qkv_kernel, n_adapters=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_block,), lambda i, j: (i,)),          # idx
            pl.BlockSpec((t_block, d), lambda i, j: (i, 0)),      # x
            pl.BlockSpec((d, o_block), lambda i, j: (0, j)),      # w
            pl.BlockSpec((n, d, r), lambda i, j: (0, 0, 0)),      # a
            pl.BlockSpec((n, r, o_block), lambda i, j: (0, 0, j)),  # b
        ],
        out_specs=pl.BlockSpec((t_block, o_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((T, out), x.dtype),
        interpret=interpret,
    )(adapter_idx, x, w, a_stack, b_stack)
