"""Ragged grouped-LoRA delta — Pallas TPU kernel (+ jnp reference).

The serving engine's mixed step used to accumulate the multi-adapter
low-rank delta with a dense stacked scan over EVERY adapter index in the
device stack (``repro.models.layers.lora_delta``): cost O(n_slots·T·d·r)
per projection regardless of how many adapters the batch actually uses.
With the dynamic adapter pool the device stack holds S slots cycling
through a much larger registry, while a typical step touches only a
handful — so the mixed step instead runs this SGMV-style grouped kernel
(S-LoRA / Punica lineage) over the **active-slot list**:

  delta[t] = (x[t] @ A[idx_t]) @ B[idx_t]
           = sum_{s in active_slots} ((x * [idx == s]) @ A[s]) @ B[s]

The scheduler knows exactly which slots this step's tokens reference and
hands the (pow2-bucketed, ascending, 0-padded) ``active_slots`` list to
the kernel — compute scales with slots *used in the batch*, not slots
resident, and certainly not adapters registered.  Padding entries are
slot 0, the pool's permanently-zero adapter: an exact no-op term, so no
separate count operand is needed.

TPU mapping: grid over (token tiles, output tiles); the x-tile stays
resident in VMEM across the (short, static) active-slot loop; the slot
ids arrive via scalar prefetch so each iteration dynamically indexes the
A/B slot stacks (rank r ≤ 64 keeps all slots' A/B tiles VMEM-resident).
Masked tokens contribute exact zeros, so slot summation order (ascending)
matches the dense reference bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def ragged_grouped_lora_ref(x: jax.Array, a_stack: jax.Array,
                            b_stack: jax.Array, adapter_idx: jax.Array,
                            active_slots: jax.Array) -> jax.Array:
    """jnp oracle for the grouped kernel.

    x:            (T, d)
    a_stack:      (S+1, d, r)   — slot 0 must be zeros
    b_stack:      (S+1, r, out)
    adapter_idx:  (T,) int32    — per-token slot index (0 = base)
    active_slots: (K,) int32    — ascending slot ids, padded with 0

    Returns the delta (T, out).  Summation runs in active-slot order, so
    the result is bit-identical to ``lora_delta``'s full dense scan
    (inactive slots there contribute exact zeros).
    """
    out_dim = b_stack.shape[-1]

    def body(acc, s):
        sel = ((adapter_idx == s) & (s > 0))[:, None].astype(x.dtype)
        acc = acc + ((x * sel) @ a_stack[s]) @ b_stack[s]
        return acc, None

    acc0 = jnp.zeros(x.shape[:-1] + (out_dim,), dtype=x.dtype)
    acc, _ = jax.lax.scan(body, acc0, active_slots)
    return acc


def _ragged_lora_kernel(slots_ref, idx_ref, x_ref, a_ref, b_ref, o_ref, *,
                        n_active: int):
    x = x_ref[...]                                     # (Tt, d)
    idx = idx_ref[...]                                 # (Tt,)
    acc = jnp.zeros(x.shape[:1] + o_ref.shape[1:], jnp.float32)
    for i in range(n_active):                          # static unroll
        s = slots_ref[i]                               # dynamic slot id
        sel = (idx == s) & (s > 0)
        xm = jnp.where(sel[:, None], x, jnp.zeros_like(x))
        xa = jnp.dot(xm, a_ref[s],
                     preferred_element_type=jnp.float32)    # (Tt, r)
        acc = acc + jnp.dot(xa.astype(x.dtype), b_ref[s],
                            preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def ragged_grouped_lora(x: jax.Array, a_stack: jax.Array,
                        b_stack: jax.Array, adapter_idx: jax.Array,
                        active_slots: jax.Array, *,
                        t_block: int = 256, o_block: int = 256,
                        interpret: bool = False) -> jax.Array:
    """Pallas grouped-LoRA delta.  Shapes as in the ref; T % t_block == 0
    and out % o_block == 0 (use :func:`ragged_grouped_lora_padded` for
    auto-padding call sites)."""
    T, d = x.shape
    n, _, r = a_stack.shape
    out = b_stack.shape[-1]
    K = active_slots.shape[0]
    assert T % t_block == 0 and out % o_block == 0, (T, out)
    grid = (T // t_block, out // o_block)

    kernel = functools.partial(_ragged_lora_kernel, n_active=K)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,                     # active_slots
            grid=grid,
            in_specs=[
                pl.BlockSpec((t_block,), lambda i, j, slots: (i,)),  # idx
                pl.BlockSpec((t_block, d), lambda i, j, slots: (i, 0)),
                pl.BlockSpec((n, d, r), lambda i, j, slots: (0, 0, 0)),
                pl.BlockSpec((n, r, o_block),
                             lambda i, j, slots: (0, 0, j)),
            ],
            out_specs=pl.BlockSpec((t_block, o_block),
                                   lambda i, j, slots: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((T, out), x.dtype),
        interpret=interpret,
    )(active_slots, adapter_idx, x, a_stack, b_stack)


def ragged_grouped_lora_padded(x: jax.Array, a_stack: jax.Array,
                               b_stack: jax.Array, adapter_idx: jax.Array,
                               active_slots: jax.Array, *,
                               t_block: int = 256, o_block: int = 256,
                               interpret: bool = False) -> jax.Array:
    """Shape-padding wrapper: pads T and out up to tile multiples (the
    mixed step's token axis is already pow2-bucketed; projection widths
    need not be).  Traced inline by the jitted mixed step."""
    T, d = x.shape
    out = b_stack.shape[-1]
    tb = min(t_block, max(T, 8))
    ob = min(o_block, out)
    Tp = ((T + tb - 1) // tb) * tb
    Op = ((out + ob - 1) // ob) * ob
    xp = jnp.pad(x, ((0, Tp - T), (0, 0)))
    ip = jnp.pad(adapter_idx, (0, Tp - T))
    bp = jnp.pad(b_stack, ((0, 0), (0, 0), (0, Op - out)))
    y = ragged_grouped_lora(xp, a_stack, bp, ip, active_slots,
                            t_block=tb, o_block=ob, interpret=interpret)
    return y[:T, :out]
