"""Pure-jnp oracles for the Pallas kernels (and the serving engine's
CPU execution path).

Each function here is the numerical ground truth its kernel twin in this
package must match (``tests/test_kernels.py`` sweeps shapes/dtypes and
asserts allclose in interpret mode).

These refs are ALSO the TP-sharded serving path's compute: the sharded
mixed step (``EngineConfig.mesh``) runs them under jit/GSPMD with the
K/V pools split on their KV-head (or head_dim) dim and metadata
replicated, so every op here must stay expressible as plain jnp — no
``pallas_call``, no host callbacks, no per-device shape dependence —
and partition cleanly along the head/head_dim axes (token/sequence axes
carry replicated metadata gathers; contraction over a sharded head_dim
psums).  The Pallas twins are single-device and are rejected by the
runner when a mesh is configured; ``tests/test_sharded_step.py`` holds
the refs to token-identical outputs under (data=2, model=4) sharding.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_tables: jax.Array, lengths: jax.Array, *,
                        window: int = 0) -> jax.Array:
    """Decode-time GQA attention over paged KV blocks.

    q:            (B, H, hd)           — one query token per sequence
    k_pool/v_pool:(NB, bs, KV, hd)     — global block pools
    block_tables: (B, nb) int32        — per-sequence physical block ids
                                         (padding entries may be any id)
    lengths:      (B,) int32           — valid tokens per sequence
    window:       sliding-window size (0 = full)

    Returns (B, H, hd).
    """
    B, H, hd = q.shape
    NB, bs, KV, _ = k_pool.shape
    nb = block_tables.shape[1]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)

    k = k_pool[block_tables].reshape(B, nb * bs, KV, hd)
    v = v_pool[block_tables].reshape(B, nb * bs, KV, hd)
    qr = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(nb * bs, dtype=jnp.int32)[None, :]
    valid = pos < lengths[:, None]
    if window > 0:
        valid = valid & (pos > lengths[:, None] - 1 - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def ragged_paged_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_tables: jax.Array,
                               req_rows: jax.Array, q_lens: jax.Array, *,
                               window: int = 0) -> jax.Array:
    """Mixed-batch (ragged) GQA attention over paged KV blocks.

    One query row per packed token — decode singletons and prefill-chunk
    tokens alike — each attending over its own request's blocks up to its
    causal length.  The current token's K/V must already be written to
    the pool (the mixed step writes before it reads).

    q:            (T, H, hd)           — one query row per packed token
    k_pool/v_pool:(NB, bs, KV, hd)     — global block pools
    block_tables: (R, nb) int32        — per-request physical block ids
    req_rows:     (T,) int32           — token → request row
    q_lens:       (T,) int32           — causal length per token
                                         (position + 1; 0 = masked row)

    Returns (T, H, hd).  Rows with ``q_lens == 0`` return garbage
    (uniform attention over masked keys) — callers slice them off.
    """
    T, H, hd = q.shape
    NB, bs, KV, _ = k_pool.shape
    nb = block_tables.shape[1]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)

    bt = block_tables[req_rows]                       # (T, nb)
    k = k_pool[bt].reshape(T, nb * bs, KV, hd)
    v = v_pool[bt].reshape(T, nb * bs, KV, hd)
    qr = q.reshape(T, KV, G, hd)
    s = jnp.einsum("tkgd,tskd->tkgs", qr, k,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(nb * bs, dtype=jnp.int32)[None, :]
    valid = pos < q_lens[:, None]
    if window > 0:
        valid = valid & (pos > q_lens[:, None] - 1 - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("tkgs,tskd->tkgd", p, v.astype(jnp.float32))
    return out.reshape(T, H, hd).astype(q.dtype)


def ssd_chunk_ref(x: jax.Array, B: jax.Array, C: jax.Array,
                  dA: jax.Array, dt: jax.Array):
    """Token-by-token SSD recurrence oracle.

    x: (Bt, S, H, P); B/C: (Bt, S, H, N); dA/dt: (Bt, S, H).
      state_t = exp(dA_t)·state_{t-1} + dt_t·(B_t ⊗ x_t)
      y_t     = C_t · state_t
    Returns (y (Bt,S,H,P) in x.dtype, final_state (Bt,H,N,P) fp32).
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]

    def step(state, inp):
        x_t, b_t, c_t, da_t, dt_t = inp
        state = jnp.exp(da_t)[..., None, None] * state + \
            jnp.einsum("bhn,bhp->bhnp", b_t * dt_t[..., None],
                       x_t.astype(jnp.float32))
        y_t = jnp.einsum("bhn,bhnp->bhp", c_t, state)
        return state, y_t

    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          B.swapaxes(0, 1).astype(jnp.float32),
          C.swapaxes(0, 1).astype(jnp.float32),
          dA.swapaxes(0, 1), dt.swapaxes(0, 1))
    state0 = jnp.zeros((Bt, H, N, P), jnp.float32)
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), final


def ragged_ssd_scan_ref(x: jax.Array, B: jax.Array, C: jax.Array,
                        dA: jax.Array, dt: jax.Array,
                        seg_starts: jax.Array, slot_rows: jax.Array,
                        init_states: jax.Array):
    """Ragged (packed-axis) SSD recurrence oracle — the SSM analogue of
    :func:`ragged_paged_attention_ref`.

    The mixed serving step packs every scheduled token (decode singletons
    and prefill chunks alike) along one token axis; each request's tokens
    form a contiguous segment.  At a segment start the recurrent state is
    gathered from that request's live-state slot; inside a segment the
    per-token recurrence runs unchanged:

      state_t = exp(dA_t)·state_{t-1} + dt_t·(B_t ⊗ x_t);  y_t = C_t·state_t

    x: (T, H, P); B/C: (T, H, N); dA/dt: (T, H) fp32;
    seg_starts:  (T,) bool  — token is the first of its request's segment
    slot_rows:   (T,) int32 — token → row in ``init_states``
    init_states: (S, H, N, P) fp32 — per-slot incoming recurrent state

    Returns (y (T,H,P) in x.dtype, states (T,H,N,P) fp32): the POST-token
    state at every packed position.  Callers gather segment-final rows for
    the live-state scatter-back and block-boundary rows for prefix-cache
    state snapshots (boundary-only emission is the production-kernel
    optimization; the ref keeps every row for testability).
    """
    def step(state, inp):
        x_t, b_t, c_t, da_t, dt_t, st_t, sl_t = inp
        entry = jnp.where(st_t, init_states[sl_t], state)
        state = jnp.exp(da_t)[..., None, None] * entry + \
            jnp.einsum("hn,hp->hnp", b_t * dt_t[..., None], x_t)
        y_t = jnp.einsum("hn,hnp->hp", c_t, state)
        return state, (y_t, state)

    T, H, P = x.shape
    N = B.shape[-1]
    xs = (x.astype(jnp.float32), B.astype(jnp.float32),
          C.astype(jnp.float32), dA, dt, seg_starts, slot_rows)
    state0 = jnp.zeros((H, N, P), jnp.float32)
    _, (ys, states) = jax.lax.scan(step, state0, xs)
    return ys.astype(x.dtype), states


def packed_cross_attention_ref(q: jax.Array, xk: jax.Array,
                               xv: jax.Array) -> jax.Array:
    """Per-token encoder-decoder cross attention (non-causal, unmasked).

    The mixed-batch analogue of ``models.attention.cross_attention``: one
    query row per packed token, each attending over its OWN request's
    projected encoder K/V (gathered by ``req_rows`` before the call).

    q:     (T, H, hd)
    xk/xv: (T, Se, KV, hd)

    Returns (T, H, hd).
    """
    T, H, hd = q.shape
    KV = xk.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    qr = q.reshape(T, KV, G, hd)
    s = jnp.einsum("tkgd,tskd->tkgs", qr, xk,
                   preferred_element_type=jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("tkgs,tskd->tkgd", p, xv.astype(jnp.float32))
    return out.reshape(T, H, hd).astype(q.dtype)


def alora_qkv_ref(x: jax.Array, w: jax.Array, a_stack: jax.Array,
                  b_stack: jax.Array, adapter_idx: jax.Array) -> jax.Array:
    """Fused base-projection + activation-aware masked low-rank delta.

    x:           (T, d)
    w:           (d, out)
    a_stack:     (n, d, r)   — index 0 is the zero adapter
    b_stack:     (n, r, out)
    adapter_idx: (T,) int32

    out[t] = x[t] @ w + (x[t] @ a[idx_t]) @ b[idx_t]
    """
    base = x @ w
    n = a_stack.shape[0]
    delta = jnp.zeros_like(base)
    for i in range(1, n):
        sel = (adapter_idx == i)[:, None].astype(x.dtype)
        delta = delta + ((x * sel) @ a_stack[i]) @ b_stack[i]
    return base + delta
