"""Mamba2 SSD chunk scan — Pallas TPU kernel.

The compute hot-spot of the SSM architectures (mamba2-2.7b, zamba2-2.7b):
the chunked state-space-duality scan.  Per (batch, head) the sequence is
processed in chunks of Q tokens; within a chunk the computation is three
MXU matmuls (C·Bᵀ (Q×Q), the masked-decay weighted W·x (Q×P), and the
inter-chunk C·state (Q×N)(N×P)); across chunks a (N×P) recurrent state
carries in fp32 VMEM scratch — the same accumulate-over-innermost-grid-dim
pattern as the paged-attention kernel.

TPU adaptation of the paper's (Dao & Gu) CUDA kernel: the chunk dim Q is
the MXU-aligned tile (128/256), the state (N×P ≤ 128×64) stays resident
in VMEM for the whole (b, h) row of the grid, and the decay matrix
L = exp(segsum(dA)) is built in-register from a cumulative sum rather
than shared-memory shuffles.

Semantics (matching ``repro.kernels.ref.ssd_chunk_ref``):
  state_t = exp(dA_t) · state_{t-1} + dt_t · B_t ⊗ x_t
  y_t     = C_t · state_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, da_ref, dt_ref, y_ref, st_ref,
                state_scr, *, Q: int):
    c_idx = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)            # (Q, P)
    B = b_ref[0, :, 0].astype(jnp.float32)            # (Q, N)
    C = c_ref[0, :, 0].astype(jnp.float32)            # (Q, N)
    dA = da_ref[0, :, 0]                              # (Q,)
    dt = dt_ref[0, :, 0]                              # (Q,)

    csum = jnp.cumsum(dA)                             # (Q,)
    total = csum[-1]
    # intra-chunk: y_diag[q] = sum_{k<=q} C_q·B_k e^{csum_q-csum_k} dt_k x_k
    diff = csum[:, None] - csum[None, :]              # (Q, Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(qi >= ki, jnp.exp(diff), 0.0)
    CB = jnp.dot(C, B.T, preferred_element_type=jnp.float32)
    W = CB * L * dt[None, :]
    y = jnp.dot(W, x, preferred_element_type=jnp.float32)
    # inter-chunk: contribution of the carried state
    state = state_scr[...]
    y = y + jnp.dot(C * jnp.exp(csum)[:, None], state,
                    preferred_element_type=jnp.float32)
    # state update
    decay = jnp.exp(total - csum) * dt                # (Q,)
    state = jnp.exp(total) * state + \
        jnp.dot((B * decay[:, None]).T, x,
                preferred_element_type=jnp.float32)
    state_scr[...] = state
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(c_idx == nc - 1)
    def _fin():
        st_ref[0, 0] = state.astype(st_ref.dtype)


def ssd_chunk_scan(x: jax.Array, B: jax.Array, C: jax.Array,
                   dA: jax.Array, dt: jax.Array, *, chunk: int = 128,
                   interpret: bool = False):
    """x: (Bt, S, H, P); B/C: (Bt, S, H, N); dA/dt: (Bt, S, H) fp32.
    S % chunk == 0 (use ``repro.kernels.ops.ssd_chunk_scan_op`` for
    auto-padding).  Returns (y (Bt,S,H,P), final_state (Bt,H,N,P))."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    grid = (Bt, H, nc)                                # chunk innermost

    kernel = functools.partial(_ssd_kernel, Q=chunk)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P),
                         lambda b, h, c: (b, c, h, 0)),   # x
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c: (b, c, h, 0)),   # B
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c: (b, c, h, 0)),   # C
            pl.BlockSpec((1, chunk, 1),
                         lambda b, h, c: (b, c, h)),      # dA
            pl.BlockSpec((1, chunk, 1),
                         lambda b, h, c: (b, c, h)),      # dt
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P),
                         lambda b, h, c: (b, c, h, 0)),   # y
            pl.BlockSpec((1, 1, N, P),
                         lambda b, h, c: (b, h, 0, 0)),   # final state
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bt, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, B, C, dA, dt)
    return y, st


# ---------------------------------------------------------------------------
# Ragged (packed-axis) variant — the mixed serving step's SSD scan
# ---------------------------------------------------------------------------
def _ragged_ssd_kernel(x_ref, b_ref, c_ref, da_ref, dt_ref, sid_ref,
                       start_ref, slot_ref, init_ref, y_ref, st_ref,
                       state_scr, *, Q: int):
    """Segment-boundary-aware SSD chunk over the PACKED token axis.

    One chunk may span several request segments: the decay matrix is
    additionally masked to same-segment pairs, and each token's entry
    state is either the scratch carry (segment spans the chunk boundary)
    or a row of the live-state pool gathered at the segment's in-chunk
    start.  Emits the post-token state at every position (the interpret-
    mode contract; a production TPU kernel would emit only block-boundary
    rows and fold y into the three-matmul form of ``_ssd_kernel``).
    """
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[:, 0].astype(jnp.float32)               # (Q, P)
    B = b_ref[:, 0].astype(jnp.float32)               # (Q, N)
    C = c_ref[:, 0].astype(jnp.float32)               # (Q, N)
    dA = da_ref[:, 0]                                 # (Q,)
    dt = dt_ref[:, 0]                                 # (Q,)
    sid = sid_ref[...]                                # (Q,) int32
    is_start = start_ref[...]                         # (Q,) int32
    slots = slot_ref[...]                             # (Q,) int32
    init_states = init_ref[:, 0].astype(jnp.float32)  # (S, N, P)
    N, P = state_scr.shape

    csum = jnp.cumsum(dA)                             # (Q,)
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    same = sid[:, None] == sid[None, :]
    # intra-chunk state contributions: SW[q,k] = e^{csum_q - csum_k}·dt_k
    # over same-segment causal pairs, applied to B_k ⊗ x_k (one Q×Q MXU
    # matmul over the flattened (N·P) state)
    SW = jnp.where((qi >= ki) & same,
                   jnp.exp(csum[:, None] - csum[None, :]), 0.0) * dt[None, :]
    Bx = (B[:, :, None] * x[:, None, :]).reshape(Q, N * P)
    states = jnp.dot(SW, Bx,
                     preferred_element_type=jnp.float32).reshape(Q, N, P)
    # entry states: scratch carry, or the pool row gathered at the most
    # recent in-chunk segment start
    tok = jax.lax.broadcasted_iota(jnp.int32, (Q,), 0)
    run_start = jax.lax.cummax(jnp.where(is_start > 0, tok, -1))
    has_start = run_start >= 0
    rs = jnp.maximum(run_start, 0)
    e0 = jnp.where(has_start, csum[rs] - dA[rs], 0.0)
    entry = jnp.where(has_start[:, None, None],
                      init_states[slots[rs]], state_scr[...])
    states = states + jnp.exp(csum - e0)[:, None, None] * entry
    y = jnp.einsum("qn,qnp->qp", C, states)
    state_scr[...] = states[Q - 1]
    y_ref[:, 0] = y.astype(y_ref.dtype)
    st_ref[:, 0] = states.astype(st_ref.dtype)


def ragged_ssd_chunk_scan(x: jax.Array, B: jax.Array, C: jax.Array,
                          dA: jax.Array, dt: jax.Array, seg_ids: jax.Array,
                          seg_starts: jax.Array, slot_rows: jax.Array,
                          init_states: jax.Array, *, chunk: int = 64,
                          interpret: bool = False):
    """Ragged SSD scan over a packed token axis (mixed serving batch).

    x: (T, H, P); B/C: (T, H, N); dA/dt: (T, H) fp32; seg_ids /
    seg_starts / slot_rows: (T,) int32; init_states: (S, H, N, P) fp32.
    T % chunk == 0 (``repro.kernels.ops.ragged_ssd_scan_op`` auto-pads).
    Returns (y (T,H,P), states (T,H,N,P) fp32 — post-token states).
    Matches ``repro.kernels.ref.ragged_ssd_scan_ref``.
    """
    T, H, P = x.shape
    N = B.shape[-1]
    S = init_states.shape[0]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    grid = (H, nc)                                    # chunk innermost

    kernel = functools.partial(_ragged_ssd_kernel, Q=chunk)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, 1, P), lambda h, c: (c, h, 0)),   # x
            pl.BlockSpec((chunk, 1, N), lambda h, c: (c, h, 0)),   # B
            pl.BlockSpec((chunk, 1, N), lambda h, c: (c, h, 0)),   # C
            pl.BlockSpec((chunk, 1), lambda h, c: (c, h)),         # dA
            pl.BlockSpec((chunk, 1), lambda h, c: (c, h)),         # dt
            pl.BlockSpec((chunk,), lambda h, c: (c,)),             # seg_ids
            pl.BlockSpec((chunk,), lambda h, c: (c,)),             # starts
            pl.BlockSpec((chunk,), lambda h, c: (c,)),             # slots
            pl.BlockSpec((S, 1, N, P), lambda h, c: (0, h, 0, 0)),  # init
        ],
        out_specs=[
            pl.BlockSpec((chunk, 1, P), lambda h, c: (c, h, 0)),   # y
            pl.BlockSpec((chunk, 1, N, P),
                         lambda h, c: (c, h, 0, 0)),               # states
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, H, P), x.dtype),
            jax.ShapeDtypeStruct((T, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, B, C, dA, dt, seg_ids, seg_starts, slot_rows, init_states)
    return y, st
