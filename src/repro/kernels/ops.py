"""jit'd public wrappers for the Pallas kernels: shape padding, dtype
handling, and CPU fallback (interpret mode) so the same call sites work
in tests (CPU) and production (TPU)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.alora_qkv import alora_qkv
from repro.kernels.paged_attention import (paged_attention,
                                           ragged_paged_attention)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("t_block", "o_block", "interpret"))
def alora_qkv_op(x: jax.Array, w: jax.Array, a_stack: jax.Array,
                 b_stack: jax.Array, adapter_idx: jax.Array, *,
                 t_block: int = 256, o_block: int = 256,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Padded/jitted fused aLoRA projection.  x: (T, d) -> (T, out)."""
    if interpret is None:
        interpret = not _on_tpu()
    T, d = x.shape
    out = w.shape[1]
    tb = min(t_block, max(T, 8))
    ob = min(o_block, out)
    Tp = ((T + tb - 1) // tb) * tb
    Op = ((out + ob - 1) // ob) * ob
    xp = jnp.pad(x, ((0, Tp - T), (0, 0)))
    ip = jnp.pad(adapter_idx, (0, Tp - T))
    wp = jnp.pad(w, ((0, 0), (0, Op - out)))
    bp = jnp.pad(b_stack, ((0, 0), (0, 0), (0, Op - out)))
    y = alora_qkv(xp, wp, a_stack, bp, ip, t_block=tb, o_block=ob,
                  interpret=interpret)
    return y[:T, :out]


@partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention_op(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       block_tables: jax.Array, lengths: jax.Array, *,
                       window: int = 0,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Paged GQA decode attention.  q: (B, H, hd) -> (B, H, hd)."""
    if interpret is None:
        interpret = not _on_tpu()
    return paged_attention(q, k_pool, v_pool, block_tables, lengths,
                           window=window, interpret=interpret)


@partial(jax.jit, static_argnames=("window", "interpret"))
def ragged_paged_attention_op(q: jax.Array, k_pool: jax.Array,
                              v_pool: jax.Array, block_tables: jax.Array,
                              req_rows: jax.Array, q_lens: jax.Array, *,
                              window: int = 0,
                              interpret: Optional[bool] = None
                              ) -> jax.Array:
    """Mixed-batch ragged paged attention.  q: (T, H, hd) -> (T, H, hd)."""
    if interpret is None:
        interpret = not _on_tpu()
    return ragged_paged_attention(q, k_pool, v_pool, block_tables,
                                  req_rows, q_lens, window=window,
                                  interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan_op(x: jax.Array, B: jax.Array, C: jax.Array,
                      dA: jax.Array, dt: jax.Array, *, chunk: int = 128,
                      interpret: Optional[bool] = None):
    """Padded/jitted SSD chunk scan.  Pads S to a chunk multiple with
    dt=0 (decay 1, zero input ⇒ state invariant)."""
    from repro.kernels.ssd_chunk import ssd_chunk_scan
    if interpret is None:
        interpret = not _on_tpu()
    Bt, S, H, P = x.shape
    ch = min(chunk, max(S, 8))
    Sp = ((S + ch - 1) // ch) * ch
    pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
    xp = jnp.pad(x, pad)
    Bp = jnp.pad(B, pad[:2] + ((0, 0), (0, 0)))
    Cp = jnp.pad(C, pad[:2] + ((0, 0), (0, 0)))
    dAp = jnp.pad(dA, ((0, 0), (0, Sp - S), (0, 0)))
    dtp = jnp.pad(dt, ((0, 0), (0, Sp - S), (0, 0)))
    y, st = ssd_chunk_scan(xp, Bp, Cp, dAp, dtp, chunk=ch,
                           interpret=interpret)
    return y[:, :S], st


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ragged_ssd_scan_op(x: jax.Array, B: jax.Array, C: jax.Array,
                       dA: jax.Array, dt: jax.Array, seg_ids: jax.Array,
                       seg_starts: jax.Array, slot_rows: jax.Array,
                       init_states: jax.Array, *, chunk: int = 64,
                       interpret: Optional[bool] = None):
    """Padded/jitted ragged SSD scan over a packed token axis.

    Pads T to a chunk multiple with dA=dt=0 (decay 1, zero input ⇒ carry
    invariant) and seg_starts=0 (padding continues the trailing segment,
    whose emitted rows the caller never gathers)."""
    from repro.kernels.ssd_chunk import ragged_ssd_chunk_scan
    if interpret is None:
        interpret = not _on_tpu()
    T = x.shape[0]
    ch = min(chunk, max(T, 8))
    Tp = ((T + ch - 1) // ch) * ch
    pad = Tp - T
    xp = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
    Bp = jnp.pad(B, ((0, pad), (0, 0), (0, 0)))
    Cp = jnp.pad(C, ((0, pad), (0, 0), (0, 0)))
    dAp = jnp.pad(dA, ((0, pad), (0, 0)))
    dtp = jnp.pad(dt, ((0, pad), (0, 0)))
    sidp = jnp.pad(seg_ids, (0, pad), mode="edge") if pad else seg_ids
    stp = jnp.pad(seg_starts.astype(jnp.int32), (0, pad))
    slp = jnp.pad(slot_rows, (0, pad), mode="edge") if pad else slot_rows
    y, st = ragged_ssd_chunk_scan(xp, Bp, Cp, dAp, dtp, sidp, stp, slp,
                                  init_states, chunk=ch,
                                  interpret=interpret)
    return y[:T], st[:T]


@partial(jax.jit, static_argnames=("t_block", "o_block", "interpret"))
def ragged_lora_op(x: jax.Array, a_stack: jax.Array, b_stack: jax.Array,
                   adapter_idx: jax.Array, active_slots: jax.Array, *,
                   t_block: int = 256, o_block: int = 256,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Padded/jitted SGMV-style grouped-LoRA delta over per-token slot
    indices.  x: (T, d) -> (T, out)."""
    from repro.kernels.ragged_lora import ragged_grouped_lora_padded
    if interpret is None:
        interpret = not _on_tpu()
    return ragged_grouped_lora_padded(x, a_stack, b_stack, adapter_idx,
                                      active_slots, t_block=t_block,
                                      o_block=o_block, interpret=interpret)


# pure-jnp oracles re-exported for benchmarks/tests
paged_attention_ref = ref.paged_attention_ref
ragged_paged_attention_ref = ref.ragged_paged_attention_ref
alora_qkv_ref = ref.alora_qkv_ref
ssd_chunk_ref = ref.ssd_chunk_ref
ragged_ssd_scan_ref = ref.ragged_ssd_scan_ref
packed_cross_attention_ref = ref.packed_cross_attention_ref


def ragged_lora_ref(x, a_stack, b_stack, adapter_idx, active_slots):
    from repro.kernels.ragged_lora import ragged_grouped_lora_ref
    return ragged_grouped_lora_ref(x, a_stack, b_stack, adapter_idx,
                                   active_slots)
