"""Paged decode attention — Pallas TPU kernel.

The decode-side hot spot of the serving engine: one query token per
sequence attends over KV stored in non-contiguous PagedAttention blocks.

TPU adaptation of the CUDA PagedAttention kernel (DESIGN.md §2): the
per-sequence block table lives in SMEM via **scalar prefetch**, so the
BlockSpec ``index_map`` of the K/V pools can translate (sequence, kv
head, block-step) grid coordinates into *physical* block ids — the
gather happens in the HBM→VMEM DMA itself, no materialized (B, S, ...)
gather.  Online softmax runs in fp32 VMEM scratch across the block-step
grid dimension (innermost, so the accumulator carries correctly), with
GQA handled by blocking all G query heads of one KV head together
(G × hd tile on the MXU per step).

Sliding windows mask positions ≤ len-1-W (the engine keeps whole blocks;
ring-buffer compaction is the dense serve-path's job).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref,
                       o_ref, m_scr, l_scr, acc_scr, *,
                       bs: int, window: int, scale: float):
    b = pl.program_id(0)
    ib = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(ib == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                   # (G, hd)
    k = k_ref[0, :, 0]                                # (bs, hd)
    v = v_ref[0, :, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    length = lengths_ref[b]
    pos = ib * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < length
    if window > 0:
        valid = valid & (pos > length - 1 - window)
    s = jnp.where(valid, s, NEG_INF)                  # (G, bs)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + \
        jnp.dot(p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ib == nb - 1)
    def _fin():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _ragged_paged_attn_kernel(tables_ref, rows_ref, lens_ref, q_ref, k_ref,
                              v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                              bs: int, window: int, scale: float):
    # the body is the dense-batch kernel with grid axis 0 meaning "token"
    # instead of "sequence"; rows_ref is consumed by the BlockSpec
    # index_maps (token → its request's block-table row), not here
    _paged_attn_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                       m_scr, l_scr, acc_scr, bs=bs, window=window,
                       scale=scale)


def ragged_paged_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           req_rows: jax.Array, q_lens: jax.Array, *,
                           window: int = 0,
                           interpret: bool = False) -> jax.Array:
    """Mixed-batch variant of :func:`paged_attention`: one query row per
    packed token (decode singletons and prefill-chunk tokens in the same
    launch), with a second scalar-prefetch indirection ``req_rows`` so the
    K/V index_map resolves (token, block-step) → the token's *request's*
    physical block.

    q: (T, H, hd); k_pool/v_pool: (NB, bs, KV, hd);
    block_tables: (R, nb) int32; req_rows: (T,) int32;
    q_lens: (T,) int32 — causal length per token (position + 1).
    Returns (T, H, hd).  Matches
    ``repro.kernels.ref.ragged_paged_attention_ref``."""
    T, H, hd = q.shape
    NB, bs, KV, _ = k_pool.shape
    nb = block_tables.shape[1]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)

    qr = q.reshape(T, KV, G, hd)
    kernel = functools.partial(_ragged_paged_attn_kernel, bs=bs,
                               window=window, scale=scale)
    grid = (T, KV, nb)                     # block-step innermost

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda t, kv, ib, tables, rows, lens:
                             (t, kv, 0, 0)),                      # q
                pl.BlockSpec((1, bs, 1, hd),
                             lambda t, kv, ib, tables, rows, lens:
                             (tables[rows[t], ib], 0, kv, 0)),    # k
                pl.BlockSpec((1, bs, 1, hd),
                             lambda t, kv, ib, tables, rows, lens:
                             (tables[rows[t], ib], 0, kv, 0)),    # v
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda t, kv, ib, tables, rows, lens:
                                   (t, kv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),        # m
                pltpu.VMEM((G,), jnp.float32),        # l
                pltpu.VMEM((G, hd), jnp.float32),     # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((T, KV, G, hd), q.dtype),
        interpret=interpret,
    )(block_tables, req_rows, q_lens, qr, k_pool, v_pool)
    return out.reshape(T, H, hd)


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array, *,
                    window: int = 0, interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k_pool/v_pool: (NB, bs, KV, hd);
    block_tables: (B, nb) int32; lengths: (B,) int32.  Returns (B, H, hd).
    Matches ``repro.kernels.ref.paged_attention_ref``."""
    B, H, hd = q.shape
    NB, bs, KV, _ = k_pool.shape
    nb = block_tables.shape[1]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)

    qr = q.reshape(B, KV, G, hd)
    kernel = functools.partial(_paged_attn_kernel, bs=bs, window=window,
                               scale=scale)
    grid = (B, KV, nb)                     # block-step innermost

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, kv, ib, tables, lens:
                             (b, kv, 0, 0)),                     # q
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, kv, ib, tables, lens:
                             (tables[b, ib], 0, kv, 0)),         # k
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, kv, ib, tables, lens:
                             (tables[b, ib], 0, kv, 0)),         # v
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, kv, ib, tables, lens:
                                   (b, kv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),        # m
                pltpu.VMEM((G,), jnp.float32),        # l
                pltpu.VMEM((G, hd), jnp.float32),     # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, qr, k_pool, v_pool)
    return out.reshape(B, H, hd)
