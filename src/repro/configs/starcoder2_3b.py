"""starcoder2-3b — dense GQA with native sliding-window attention.
[arXiv:2402.19173]

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152, window 4096.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    activation="gelu",
    rope_theta=999_999.0,
    sliding_window=4096,       # model-card native window
    source="arXiv:2402.19173",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="starcoder2-3b-reduced",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=512, max_seq_len=1024,
        sliding_window=128, dtype="float32",
    )
