"""phi3.5-moe-42b-a6.6b — MoE, 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct]

32L d_model=4096 32H (GQA kv=8) per-expert d_ff=6400 vocab=32064.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    activation="swiglu",
    moe=MoEConfig(num_experts=16, experts_per_token=2, d_ff=6400),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="phi3.5-moe-reduced",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512, max_seq_len=1024,
        moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff=256),
        dtype="float32",
    )
