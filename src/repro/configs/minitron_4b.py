"""minitron-4b — pruned nemotron (dense GQA, squared-ReLU). [arXiv:2407.14679]

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    activation="squared_relu",
    rope_theta=10_000.0,
    source="arXiv:2407.14679",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="minitron-4b-reduced",
        num_layers=2, d_model=192, num_heads=6, num_kv_heads=2,
        head_dim=32, d_ff=384, vocab_size=512, max_seq_len=1024,
        dtype="float32",
    )
