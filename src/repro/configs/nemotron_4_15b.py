"""nemotron-4-15b — dense GQA, squared-ReLU MLP. [arXiv:2402.16819]

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    activation="squared_relu",
    rope_theta=10_000.0,
    source="arXiv:2402.16819",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="nemotron-4-15b-reduced",
        num_layers=2, d_model=192, num_heads=6, num_kv_heads=2,
        head_dim=32, d_ff=768, vocab_size=512, max_seq_len=1024,
        dtype="float32",
    )
