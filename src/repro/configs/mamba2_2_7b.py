"""mamba2-2.7b — pure SSM (SSD / state-space duality). [arXiv:2405.21060]

64L d_model=2560 (attention-free) vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,           # unused for pure-SSM stacks
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256,
                  conv_width=4, ngroups=1),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="mamba2-2.7b-reduced",
        num_layers=2, d_model=128, vocab_size=512, max_seq_len=1024,
        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, chunk_size=64,
                      conv_width=4, ngroups=1),
        dtype="float32",
    )
