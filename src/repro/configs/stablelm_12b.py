"""stablelm-12b — dense GQA decoder. [hf:stabilityai/stablelm-2-1_6b family]

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    activation="swiglu",
    rope_theta=10_000.0,
    source="hf:stabilityai/stablelm-2-1_6b (scaled family member)",
)


def reduced() -> ModelConfig:
    """2-layer, d<=512 smoke variant of the same (dense GQA swiglu) family."""
    return CONFIG.replace(
        name="stablelm-12b-reduced",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=512, max_seq_len=1024,
        dtype="float32",
    )
