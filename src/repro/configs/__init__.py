"""Architecture registry.

``get_config(arch_id)`` returns the full (production) config; ``get_reduced``
returns the CPU smoke-test variant of the same family.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig  # noqa: F401
from repro.configs import (
    granite3_8b,
    granite_moe_1b,
    mamba2_2_7b,
    minitron_4b,
    nemotron_4_15b,
    phi3_5_moe,
    phi3_vision_4_2b,
    stablelm_12b,
    starcoder2_3b,
    whisper_large_v3,
    zamba2_2_7b,
)

_MODULES = {
    "stablelm-12b": stablelm_12b,
    "nemotron-4-15b": nemotron_4_15b,
    "mamba2-2.7b": mamba2_2_7b,
    "starcoder2-3b": starcoder2_3b,
    "whisper-large-v3": whisper_large_v3,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe,
    "minitron-4b": minitron_4b,
    "zamba2-2.7b": zamba2_2_7b,
    "phi-3-vision-4.2b": phi3_vision_4_2b,
    "granite-moe-1b-a400m": granite_moe_1b,
    # the paper's own model (not part of the assigned pool of 10)
    "granite-3.2-8b": granite3_8b,
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "granite-3.2-8b"]


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {sorted(_MODULES)}")
    return _MODULES[arch_id].CONFIG


def get_reduced(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {sorted(_MODULES)}")
    return _MODULES[arch_id].reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {k: m.CONFIG for k, m in _MODULES.items()}


def get_input_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
