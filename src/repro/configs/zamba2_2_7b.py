"""zamba2-2.7b — hybrid Mamba2 + shared attention blocks. [arXiv:2411.15242]

54L d_model=2560 32H (kv=32, MHA in the attention blocks) d_ff=10240
vocab=32000, ssm_state=64.  The stack is mostly Mamba2 blocks with an
attention(+MLP) block interleaved every 6 layers (the paper's shared
attention block, unrolled).
"""
from repro.configs.base import ATTN, SSM, ModelConfig, SSMConfig

_PATTERN = tuple(ATTN if (i % 6) == 5 else SSM for i in range(54))

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    activation="swiglu",
    layer_pattern=_PATTERN,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_size=256,
                  conv_width=4, ngroups=1),
    source="arXiv:2411.15242",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="zamba2-2.7b-reduced",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=256, vocab_size=512, max_seq_len=1024,
        layer_pattern=(SSM, SSM, ATTN, SSM),
        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, chunk_size=64,
                      conv_width=4, ngroups=1),
        dtype="float32",
    )
