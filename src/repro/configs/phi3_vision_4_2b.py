"""phi-3-vision-4.2b — VLM backbone (phi3-mini + CLIP).
[hf:microsoft/Phi-3-vision-128k-instruct]

32L d_model=3072 32H (kv=32, MHA) d_ff=8192 vocab=32064.  The CLIP/ViT
vision encoder + projector is a STUB: ``input_specs`` provides precomputed
patch embeddings (B, num_patches, d_model) which are prepended to the
token embeddings as ordinary prefix tokens — their KV blocks participate
in cross-model prefix-cache reuse like any text block.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    activation="swiglu",
    rope_theta=10_000.0,
    frontend="vision",
    num_patches=576,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="phi-3-vision-reduced",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=8,
        head_dim=32, d_ff=512, vocab_size=512, num_patches=16,
        max_seq_len=1024, dtype="float32",
    )
