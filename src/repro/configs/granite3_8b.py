"""granite-3.2-8b analogue — the paper's own evaluation model (Table 1).

Used by the benchmark pipelines (at reduced scale on CPU) so the
experiments mirror the paper's Granite 3.2 8B setup.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3.2-8b",
    arch_type="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    activation="swiglu",
    tie_embeddings=True,
    source="paper Table 1 / hf:ibm-granite/granite-3.2-8b-instruct",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="granite-3.2-8b-reduced",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=512, max_seq_len=2048,
        dtype="float32",
    )
