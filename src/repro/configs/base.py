"""Model / run configuration system.

Every architecture in the assigned pool is expressed as a frozen
:class:`ModelConfig`.  Configs are *data*: the model zoo in
``repro.models`` consumes them, the launcher selects them by ``--arch``,
and each config module also exposes ``reduced()`` returning a tiny
CPU-runnable variant of the same family for smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer kinds used in ``layer_pattern`` for hybrid architectures.
ATTN = "attn"
SSM = "ssm"


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for the MLP sublayer."""

    num_experts: int
    experts_per_token: int
    d_ff: int                      # per-expert hidden width
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings."""

    state_dim: int                 # N — SSM state size per head
    head_dim: int = 64             # P — channels per SSM head
    expand: int = 2                # d_inner = expand * d_model
    chunk_size: int = 256          # SSD chunk length
    conv_width: int = 4            # depthwise causal conv window
    ngroups: int = 1               # B/C groups (Mamba2 uses 1..8)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``head_dim`` is explicit (not always d_model // num_heads in modern
    models).  ``layer_pattern`` describes hybrid stacks; when ``None`` the
    stack is homogeneous (all-attn for dense, all-ssm for pure SSM).
    """

    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    activation: str = "swiglu"     # swiglu | squared_relu | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    max_seq_len: int = 131_072
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # --- attention variant -------------------------------------------------
    sliding_window: int = 0        # 0 = full causal attention
    # window used when a full-attention arch is lowered for long_500k:
    long_context_window: int = 8192
    # --- optional subsystems ------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    layer_pattern: Optional[Tuple[str, ...]] = None
    # --- encoder-decoder (whisper) ------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0       # fixed frames from the audio frontend stub
    # --- modality frontend stubs --------------------------------------------
    frontend: str = "none"         # none | audio | vision
    num_patches: int = 0           # vision: patch embeddings prepended
    # --- citation ------------------------------------------------------------
    source: str = ""

    # -- derived -------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % self.num_kv_heads == 0, (
            f"{self.name}: num_heads={self.num_heads} not a multiple of "
            f"num_kv_heads={self.num_kv_heads}")
        if self.layer_pattern is not None:
            assert len(self.layer_pattern) == self.num_layers

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern is not None:
            return self.layer_pattern
        if self.arch_type == "ssm":
            return tuple([SSM] * self.num_layers)
        return tuple([ATTN] * self.num_layers)

    def num_attn_layers(self) -> int:
        return sum(1 for k in self.pattern() if k == ATTN)

    def num_ssm_layers(self) -> int:
        return sum(1 for k in self.pattern() if k == SSM)

    # -- parameter counting (used by rooflines / MODEL_FLOPS) ----------------
    def param_count(self) -> int:
        """Total parameters (embeddings included once; tied -> once)."""
        d = self.d_model
        n = 0
        emb = self.vocab_size * d
        n += emb if self.tie_embeddings else 2 * emb
        attn = self._attn_params()
        mlp = self._mlp_params()
        ssm = self._ssm_params()
        for kind in self.pattern():
            if kind == ATTN:
                n += attn + mlp
            else:
                n += ssm
        if self.is_encoder_decoder:
            # encoder self-attn (MHA) + mlp, decoder adds cross-attn
            n += self.num_encoder_layers * (attn + mlp)
            n += self.num_layers * attn        # cross-attention blocks
        # norms are negligible but counted for honesty
        n += (self.num_layers * 2 + 1) * d
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        total_moe = self.num_attn_layers() * self._mlp_params()
        m = self.moe
        dense_equiv_ff = 3 * d * m.d_ff * m.experts_per_token
        router = d * m.num_experts
        return full - total_moe + self.num_attn_layers() * (dense_equiv_ff + router)

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        return q + kv + o

    def _mlp_params(self) -> int:
        d = self.d_model
        if self.moe is not None:
            m = self.moe
            gate_mult = 3 if self.activation == "swiglu" else 2
            return m.num_experts * gate_mult * d * m.d_ff + d * m.num_experts
        gate_mult = 3 if self.activation == "swiglu" else 2
        return gate_mult * d * self.d_ff

    def _ssm_params(self) -> int:
        if self.ssm is None:
            return 0
        s = self.ssm
        d = self.d_model
        d_inner = s.expand * d
        nheads = d_inner // s.head_dim
        # split input projection in_z/in_xbc/in_dt -> [z | xBC | dt]
        # (same total as the former fused in_proj matrix)
        in_proj = d * (2 * d_inner + 2 * s.ngroups * s.state_dim + nheads)
        conv = s.conv_width * (d_inner + 2 * s.ngroups * s.state_dim)
        out_proj = d_inner * d
        extra = nheads * 2 + d_inner   # A_log, dt_bias, D(+norm)
        return in_proj + conv + out_proj + extra

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (global).
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
