"""whisper-large-v3 — audio encoder-decoder backbone. [arXiv:2212.04356]

32L (decoder) d_model=1280 20H (kv=20, MHA) d_ff=5120 vocab=51866.
Conv/mel frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings of shape (B, 1500, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    activation="gelu",
    is_encoder_decoder=True,
    num_encoder_layers=32,
    encoder_seq_len=1500,
    frontend="audio",
    max_seq_len=1_048_576,   # backbone exercised generically per assignment
    source="arXiv:2212.04356",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-large-v3-reduced",
        num_layers=2, num_encoder_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        encoder_seq_len=64, max_seq_len=1024, dtype="float32",
    )
