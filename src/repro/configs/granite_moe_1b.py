"""granite-moe-1b-a400m — MoE, 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]

24L d_model=1024 16H (GQA kv=8) per-expert d_ff=512 vocab=49155.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    activation="swiglu",
    moe=MoEConfig(num_experts=32, experts_per_token=8, d_ff=512),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="granite-moe-reduced",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=128, vocab_size=512, max_seq_len=1024,
        moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff=128),
        dtype="float32",
    )
