"""Hot-path-safe trace recording: the ``Tracer`` every serving-stack
component stamps spans, events, counters and cache-reuse ledger entries
into.

Recording is APPEND-ONLY PLAIN PYTHON — no jax calls, no numpy syncs,
no device work of any kind.  Tracer methods run inside the engine's
schedule/submit phases (admission, placement probes, batch assembly),
where a single hidden device sync would stall the async pipeline once
per step — so the hot-path lint (``repro.analysis.hotpath_lint``)
checks every function in this module wholesale and rejects ANY
``jax.*``/``jnp.*`` call or blocking construct, with no annotation
escape hatch (rule ``obs-jax``/``obs-sync``).  Anything that needs
real work — byte accounting, JSON, aggregation — belongs in
``repro.obs.export``, which only ever runs off the step path.

Two timestamps ride every record:

* ``t0``/``t1`` — host wall time (``time.perf_counter()`` seconds):
  the honest timebase for per-step phase spans and cross-replica
  overlap (the async pipeline's submit/retire concurrency is a
  wall-clock fact);
* ``vclock`` — the engine's virtual clock at record time (``None``
  where no clock exists, e.g. runner/pool internals): the timebase of
  the discrete-event simulation request lifecycles live on.

Ring bounds: like the runner's ``d2h_fetches`` log, the event and
ledger rings trim their OLDEST half in bulk at ``TRACE_RING_MAX`` so a
long-lived engine never accumulates one record per step forever;
``Tracer.dropped`` counts what the trim discarded (exporters surface
it so a truncated trace is never mistaken for a complete one).

The kill switch: ``REPRO_TRACE=0`` disables recording at construction
(every method early-returns on ``self.enabled``); ``EngineConfig.trace``
overrides the environment per engine (the benchmark A/B measuring the
overhead budget documented in ``docs/observability.md``).
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional, Tuple

# bulk-trim bounds for the event + ledger rings (oldest half dropped at
# the threshold, mirroring runner.D2H_LOG_MAX/KEEP)
TRACE_RING_MAX = 65536
TRACE_RING_KEEP = 32768

# event-record field order (a plain tuple per record — the stable
# schema ``repro.obs.export`` renders; tests golden it)
EVENT_FIELDS = ("kind", "track", "name", "t0", "t1", "vclock", "args")
# ledger-record field order
LEDGER_FIELDS = ("req_id", "adapter_uid", "reused", "recomputed",
                 "state_reused", "vclock")

# track vocabulary (Perfetto thread per track, see docs/observability.md)
TRACKS = ("schedule", "submit", "retire", "pool", "router", "lifecycle")

EventRec = Tuple[str, str, str, float, float, Optional[float],
                 Optional[Dict[str, Any]]]
LedgerRec = Tuple[int, Optional[str], int, int, bool, Optional[float]]


def trace_enabled_default() -> bool:
    """Tracing is ON by default; ``REPRO_TRACE=0`` is the kill switch."""
    return os.environ.get("REPRO_TRACE", "1") != "0"


class Tracer:
    """Bounded-ring trace recorder (one per engine / router).

    All recording methods are O(1) plain-python appends and early-return
    when disabled — safe to call from schedule/submit-phase code.
    """

    def __init__(self, enabled: Optional[bool] = None, replica: int = 0):
        self.enabled = trace_enabled_default() if enabled is None \
            else bool(enabled)
        self.replica = replica
        self.events: List[EventRec] = []
        self.ledger: List[LedgerRec] = []
        self.counters: Dict[str, float] = {}
        self.dropped = 0            # records the ring trim discarded

    # ------------------------------------------------------------------
    def set_replica(self, replica: int) -> None:
        """Stamp this tracer's fleet position (the router assigns these
        so per-replica Perfetto tracks line up with placement events)."""
        self.replica = replica

    # ------------------------------------------------------------------
    def _append(self, ring: List[Any], rec: Any) -> None:
        if len(ring) >= TRACE_RING_MAX:
            drop = len(ring) - TRACE_RING_KEEP
            del ring[:drop]
            self.dropped += drop
        ring.append(rec)

    # ------------------------------------------------------------------
    def span(self, track: str, name: str, t0: float, t1: float,
             vclock: Optional[float],
             args: Optional[Dict[str, Any]] = None) -> None:
        """A completed interval [t0, t1] (wall seconds) on ``track``."""
        if not self.enabled:
            return
        self._append(self.events, ("span", track, name, t0, t1, vclock,
                                   args))

    def event(self, track: str, name: str, vclock: Optional[float],
              args: Optional[Dict[str, Any]] = None) -> None:
        """An instant event, wall-stamped here at record time."""
        if not self.enabled:
            return
        t = time.perf_counter()
        self._append(self.events, ("event", track, name, t, t, vclock,
                                   args))

    def count(self, name: str, delta: float = 1.0) -> None:
        """Bump a monotonic counter (Prometheus-counter semantics)."""
        if not self.enabled:
            return
        self.counters[name] = self.counters.get(name, 0.0) + delta

    # ------------------------------------------------------------------
    def ledger_entry(self, req_id: int, adapter_uid: Optional[str],
                     reused: int, recomputed: int, state_reused: bool,
                     vclock: Optional[float]) -> None:
        """One cache-reuse ledger row, recorded at a successful
        admission — the aLoRA switch boundary: ``adapter_uid`` is the
        model the request runs under, ``reused`` the prefix tokens the
        cache served (KV blocks prefilled by the base model or sibling
        adapters included — the paper's central quantity), ``recomputed``
        the prompt remainder prefill must execute.  Failed admissions
        (``_try_admit`` bail paths) return their acquired blocks and
        record nothing, so over a run without admission failures the
        ledger's reused-token total reconciles exactly with
        ``BlockManager.hits * block_size`` on attention-only archs."""
        if not self.enabled:
            return
        self._append(self.ledger, (req_id, adapter_uid, int(reused),
                                   int(recomputed), bool(state_reused),
                                   vclock))
        self.counters["tokens_reused_total"] = \
            self.counters.get("tokens_reused_total", 0.0) + reused
        self.counters["tokens_recomputed_total"] = \
            self.counters.get("tokens_recomputed_total", 0.0) + recomputed
        self.counters["admissions_total"] = \
            self.counters.get("admissions_total", 0.0) + 1.0

    # ------------------------------------------------------------------
    def request_summary(self, req_id: int, adapter_uid: Optional[str],
                        arrival: float, t_prefill_start: Optional[float],
                        t_decode_start: Optional[float], t_done: float,
                        prompt_len: int, output_len: int,
                        cache_hit_tokens: int) -> None:
        """The full lifecycle of a finished request, in VIRTUAL-clock
        seconds (the engine's discrete-event timebase).  Recorded once
        at finish (retire phase); the exporter expands it into
        queue/prefill/decode spans on the request timeline."""
        if not self.enabled:
            return
        t = time.perf_counter()
        self._append(self.events, (
            "request", "lifecycle", "request", t, t, t_done,
            {"req_id": req_id, "adapter_uid": adapter_uid,
             "arrival": arrival, "t_prefill_start": t_prefill_start,
             "t_decode_start": t_decode_start, "t_done": t_done,
             "prompt_len": prompt_len, "output_len": output_len,
             "cache_hit_tokens": cache_hit_tokens}))
        self.counters["requests_finished_total"] = \
            self.counters.get("requests_finished_total", 0.0) + 1.0
