"""Unified tracing + telemetry for the serving stack.

``repro.obs.tracer`` is the hot-path-safe recording core (plain-python
appends only — linted wholesale by ``repro.analysis.hotpath_lint``);
``repro.obs.export`` renders the recorded rings into Perfetto JSON,
Prometheus text and JSONL off the step path.  See
``docs/observability.md`` for the trace schema and track layout.
"""
from repro.obs.export import (
    d2h_summary,
    prometheus_text,
    reuse_by_adapter,
    to_perfetto,
    trace_records,
    write_jsonl,
    write_perfetto,
)
from repro.obs.tracer import (
    TRACE_RING_KEEP,
    TRACE_RING_MAX,
    Tracer,
    trace_enabled_default,
)

__all__ = [
    "TRACE_RING_KEEP",
    "TRACE_RING_MAX",
    "Tracer",
    "d2h_summary",
    "prometheus_text",
    "reuse_by_adapter",
    "to_perfetto",
    "trace_enabled_default",
    "trace_records",
    "write_jsonl",
    "write_perfetto",
]
