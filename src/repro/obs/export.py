"""Trace exporters — everything that turns ``Tracer`` rings into
artifacts.  Runs strictly OFF the step path (after a run, or from a
benchmark/CLI), so unlike ``repro.obs.tracer`` this module may do real
work: JSON encoding, byte accounting, aggregation.

Three formats:

* **Perfetto / Chrome trace JSON** (``to_perfetto``/``write_perfetto``):
  load the file at https://ui.perfetto.dev.  One process per replica
  carrying the step-phase tracks (schedule / submit / retire / pool) on
  the WALL-clock timebase — per-replica submit/retire overlap and fleet
  concurrency are wall-clock facts and render as literally overlapping
  slices — plus one process per replica for request lifecycles
  (queue → prefill → decode spans per request) on the VIRTUAL-clock
  timebase, and one process for the router's placement decisions.
* **Prometheus text** (``prometheus_text``): a flat counters snapshot in
  the text exposition format, one ``repro_*`` counter family per
  ``Tracer.counters`` key with a ``replica`` label — the scrape payload
  ``launch/serve.py --metrics-out`` writes.
* **JSONL** (``trace_records``/``write_jsonl``): every event, ledger row
  and counter as a flat dict — the form ``benchmarks/report.py``
  consumes for the per-adapter reuse table.

Schema details and the track layout live in ``docs/observability.md``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.tracer import EVENT_FIELDS, LEDGER_FIELDS, Tracer

# Perfetto process-id layout: phase tracks at PID_PHASE+replica,
# request lifecycles at PID_LIFECYCLE+replica, the router at PID_ROUTER
PID_PHASE = 1
PID_LIFECYCLE = 1001
PID_ROUTER = 2001
# thread id per phase track inside a replica's phase process
TRACK_TIDS = {"schedule": 1, "submit": 2, "retire": 3, "pool": 4,
              "router": 5, "lifecycle": 6}


def _us(t: Optional[float]) -> float:
    return 0.0 if t is None else t * 1e6


def _meta(pid: int, name: str, tid: Optional[int] = None,
          tname: Optional[str] = None) -> List[Dict[str, Any]]:
    """Metadata records; an empty ``name`` emits no process_name record
    (it would override the real one — later M records win in
    Perfetto)."""
    out: List[Dict[str, Any]] = []
    if name:
        out.append({"name": "process_name", "ph": "M", "pid": pid,
                    "tid": 0, "args": {"name": name}})
    if tid is not None:
        out.append({"name": "thread_name", "ph": "M", "pid": pid,
                    "tid": tid, "args": {"name": tname or ""}})
    return out


def to_perfetto(tracers: Sequence[Tracer]) -> Dict[str, Any]:
    """Chrome-trace/Perfetto JSON for a set of tracers (one per replica,
    plus optionally the router's)."""
    ev: List[Dict[str, Any]] = []
    for tr in tracers:
        if tr.replica < 0:          # the router's own tracer
            pid_phase = PID_ROUTER
            ev += _meta(pid_phase, "router")
        else:
            pid_phase = PID_PHASE + tr.replica
            ev += _meta(pid_phase, f"replica {tr.replica} · step phases")
        pid_life = PID_LIFECYCLE + max(tr.replica, 0)
        named_tids = set()
        life_named = False
        for kind, track, name, t0, t1, vclock, args in tr.events:
            if kind == "request":
                # expand the lifecycle summary into queue/prefill/decode
                # spans on the virtual-clock request process
                if not life_named:
                    ev += _meta(pid_life,
                                f"replica {max(tr.replica, 0)} · requests "
                                "(virtual clock)")
                    life_named = True
                a = args or {}
                rid = int(a.get("req_id", 0))
                tid = rid + 1
                ev += _meta(pid_life, "", tid,
                            f"req {rid} [{a.get('adapter_uid') or 'base'}]")
                bounds = [("queue", a.get("arrival"),
                           a.get("t_prefill_start")),
                          ("prefill", a.get("t_prefill_start"),
                           a.get("t_decode_start")),
                          ("decode", a.get("t_decode_start"),
                           a.get("t_done"))]
                for sname, lo, hi in bounds:
                    if lo is None or hi is None:
                        continue
                    ev.append({"name": sname, "ph": "X", "pid": pid_life,
                               "tid": tid, "ts": _us(lo),
                               "dur": max(_us(hi) - _us(lo), 0.0),
                               "args": a})
                continue
            if track == "lifecycle":
                # arrival marks etc.: virtual-clock instants on the
                # request process, threaded by request id
                if not life_named:
                    ev += _meta(pid_life,
                                f"replica {max(tr.replica, 0)} · requests "
                                "(virtual clock)")
                    life_named = True
                a = args or {}
                ev.append({"name": name, "ph": "i", "s": "t",
                           "pid": pid_life,
                           "tid": int(a.get("req_id", 0)) + 1,
                           "ts": _us(vclock), "args": a})
                continue
            tid = TRACK_TIDS.get(track, 9)
            if tid not in named_tids:
                ev += _meta(pid_phase, "", tid, track)
                named_tids.add(tid)
            rec: Dict[str, Any] = {"name": name, "pid": pid_phase,
                                   "tid": tid, "ts": _us(t0)}
            if args or vclock is not None:
                rec["args"] = dict(args or {})
                if vclock is not None:
                    rec["args"]["vclock"] = vclock
            if kind == "span":
                rec["ph"] = "X"
                rec["dur"] = max(_us(t1) - _us(t0), 0.0)
            else:
                rec["ph"] = "i"
                rec["s"] = "t"
            ev.append(rec)
        # ledger rows: instant "admit" marks on the request timeline at
        # their virtual-clock admission time (the cache-probe verdict)
        for req_id, uid, reused, recomp, state_reused, vclock in tr.ledger:
            if not life_named:
                ev += _meta(pid_life,
                            f"replica {max(tr.replica, 0)} · requests "
                            "(virtual clock)")
                life_named = True
            ev.append({"name": "admit", "ph": "i", "s": "t",
                       "pid": pid_life, "tid": req_id + 1,
                       "ts": _us(vclock),
                       "args": {"adapter_uid": uid, "reused": reused,
                                "recomputed": recomp,
                                "state_reused": state_reused}})
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def write_perfetto(path: str, tracers: Sequence[Tracer]) -> None:
    with open(path, "w") as f:
        json.dump(to_perfetto(tracers), f)


# ---------------------------------------------------------------------------
def trace_records(tracers: Sequence[Tracer]) -> List[Dict[str, Any]]:
    """Every event + ledger row + counter as flat JSONL-able dicts (the
    ``benchmarks/report.py`` input)."""
    out: List[Dict[str, Any]] = []
    for tr in tracers:
        for evt in tr.events:
            rec = dict(zip(EVENT_FIELDS, evt))
            rec["replica"] = tr.replica
            out.append(rec)
        for row in tr.ledger:
            rec = dict(zip(LEDGER_FIELDS, row))
            rec["kind"] = "ledger"
            rec["replica"] = tr.replica
            out.append(rec)
        for name, val in sorted(tr.counters.items()):
            out.append({"kind": "counter", "name": name, "value": val,
                        "replica": tr.replica})
        if tr.dropped:
            out.append({"kind": "dropped", "value": tr.dropped,
                        "replica": tr.replica})
    return out


def write_jsonl(path: str, tracers: Sequence[Tracer]) -> None:
    with open(path, "w") as f:
        for rec in trace_records(tracers):
            f.write(json.dumps(rec) + "\n")


# ---------------------------------------------------------------------------
def prometheus_text(tracers: Sequence[Tracer]) -> str:
    """Counters snapshot in the Prometheus text exposition format.
    Counter families are ``repro_<name>`` with a ``replica`` label
    (``"router"`` for the router's own tracer)."""
    by_name: Dict[str, List[Tuple[str, float]]] = {}
    for tr in tracers:
        label = "router" if tr.replica < 0 else str(tr.replica)
        for name, val in tr.counters.items():
            by_name.setdefault(name, []).append((label, val))
    lines: List[str] = []
    for name in sorted(by_name):
        fam = f"repro_{name}"
        lines.append(f"# TYPE {fam} counter")
        for label, val in sorted(by_name[name]):
            lines.append(f'{fam}{{replica="{label}"}} {val:g}')
    return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
def reuse_by_adapter(tracers: Sequence[Tracer]
                     ) -> Dict[str, Dict[str, float]]:
    """Ledger rows aggregated per adapter uid (``"base"`` for
    adapter-less requests): admissions, tokens reused vs recomputed and
    the resulting reuse fraction — the paper's central quantity as a
    table instead of a hidden counter."""
    out: Dict[str, Dict[str, float]] = {}
    for tr in tracers:
        for _req, uid, reused, recomp, state_reused, _vc in tr.ledger:
            row = out.setdefault(uid or "base", {
                "admissions": 0.0, "reused": 0.0, "recomputed": 0.0,
                "state_reuses": 0.0})
            row["admissions"] += 1
            row["reused"] += reused
            row["recomputed"] += recomp
            row["state_reuses"] += bool(state_reused)
    for row in out.values():
        tot = row["reused"] + row["recomputed"]
        row["reuse_frac"] = row["reused"] / tot if tot else 0.0
    return out


# ---------------------------------------------------------------------------
def d2h_summary(fetches: Iterable[Tuple[int, str, str]]
                ) -> Dict[str, Dict[str, float]]:
    """Aggregate a ``ModelRunner.d2h_fetches`` ring (``(elems, dtype,
    tag)`` rows) into per-tag transfer counts / element / byte totals —
    the ids-only-D2H invariant as a human-readable table."""
    out: Dict[str, Dict[str, float]] = {}
    for elems, dtype, tag in fetches:
        row = out.setdefault(tag, {"count": 0.0, "elems": 0.0,
                                   "bytes": 0.0})
        row["count"] += 1
        row["elems"] += elems
        row["bytes"] += elems * np.dtype(dtype).itemsize
    return out
