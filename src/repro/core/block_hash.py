"""Base-aligned chained block hashing — the paper's §3 core system change.

vLLM hashes each KV-cache block over (1) the tokens in the block, (2) the
hash of the parent block, (3) extra identifiers (adapter ID, cache salt).
By default every adapter gets its own hash namespace, which *isolates*
adapter caches from the base model's.

The paper's insight: for **Activated LoRA** requests, blocks that lie
entirely before the activation point produce K/V *bit-identical* to the
base model's, so the adapter ID must be **omitted** from their hash —
making them hash-equal to (and interchangeable with) base-model blocks.
Post-activation blocks (and every block of a vanilla LoRA request) keep
the adapter ID.  This single rule yields the two-way reuse of paper
Fig. 3/4: base→aLoRA and aLoRA→base (and aLoRA→sibling-aLoRA).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

BlockHash = bytes


@dataclass(frozen=True)
class AdapterKey:
    """How a request's adapter affects hashing.

    kind: "alora" (invocation-activated; pre-activation blocks are
    base-aligned) or "lora" (vanilla; every block adapter-salted).
    ``inv_start``: index of the first token of the invocation sequence
    (aLoRA only) — K/V at/after this index are adapter-specific.
    """
    adapter_id: str
    kind: str                      # "alora" | "lora"
    inv_start: int = 0


def hash_block(parent: Optional[BlockHash], tokens: Sequence[int],
               extra: Tuple = ()) -> BlockHash:
    h = hashlib.sha256()
    h.update(parent if parent is not None else b"ROOT")
    h.update(b"|")
    h.update(",".join(map(str, tokens)).encode())
    h.update(b"|")
    h.update(repr(extra).encode())
    return h.digest()[:16]


def block_extra(adapter: Optional[AdapterKey], block_start: int,
                block_end: int) -> Tuple:
    """The ``extra`` identifiers for the block [block_start, block_end).

    Base model              -> ()
    aLoRA, block entirely before the invocation start -> ()   (base-aligned!)
    aLoRA, block at/after the invocation start        -> (adapter_id,)
    vanilla LoRA            -> (adapter_id,) for every block
    """
    if adapter is None:
        return ()
    if adapter.kind == "lora":
        return (adapter.adapter_id,)
    assert adapter.kind == "alora", adapter.kind
    if block_end <= adapter.inv_start:
        return ()
    return (adapter.adapter_id,)


def request_block_hashes(tokens: Sequence[int], block_size: int,
                         adapter: Optional[AdapterKey] = None,
                         salt: Tuple = ()) -> List[BlockHash]:
    """Chained hashes for every FULL block of ``tokens``.

    Partial trailing blocks are not hashed (vLLM semantics — paper Fig. 3:
    activation tokens that don't fill a block are not cached).

    ``salt`` is vLLM's cache-salt (paper §3): extra identifiers mixed
    into EVERY block hash.  Used e.g. for multimodal requests whose
    decoder KV depends on out-of-band content (audio frames / image
    patches): the salt is a digest of that content.
    """
    out: List[BlockHash] = []
    parent: Optional[BlockHash] = None
    n_full = len(tokens) // block_size
    for i in range(n_full):
        lo, hi = i * block_size, (i + 1) * block_size
        extra = salt + block_extra(adapter, lo, hi)
        parent = hash_block(parent, tokens[lo:hi], extra)
        out.append(parent)
    return out
