"""Activation-aware masking — paper Alg. 1 / Appendix A+B.

The vLLM implementation passes a flat boolean mask
(``position_within_req < inv_start[req]``) through the forward context so
QKV layers can blend base and adapted outputs.  Our TPU-native equivalent
merges the mask and the "which adapter" choice into a single per-token
**adapter index**: 0 selects the zero adapter (base weights — used for
base-model tokens AND pre-activation tokens of an aLoRA request);
slot i>0 selects adapter i.  ``repro.models.layers.lora_delta`` consumes
these indices inside the jitted graph, preserving XLA fusion the same way
the paper's static mask preserves the torch graph.

Functions here are host-side (numpy) — they run in the scheduler/model-
runner metadata path, mirroring the paper's ``build_alora_metadata``.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np


def find_invocation_start(tokens: Sequence[int],
                          invocation_tokens: Sequence[int]) -> Optional[int]:
    """Index of the first token of the LAST occurrence of the invocation
    sequence in ``tokens`` (None if absent).

    aLoRA requests are identified by the presence of an
    ``invocation_tokens`` field in the adapter config (paper §3); the
    location of the activation sequence in the prompt is recorded here.
    """
    inv = list(invocation_tokens)
    if not inv:
        return None
    toks = list(tokens)
    n, m = len(toks), len(inv)
    for start in range(n - m, -1, -1):
        if toks[start:start + m] == inv:
            return start
    return None


def adapter_index_for_positions(positions: np.ndarray, slot: int,
                                kind: str, inv_start: int) -> np.ndarray:
    """Per-token adapter index for one request.

    positions: absolute token positions within the request (any shape).
    vanilla "lora": the adapter applies everywhere.
    "alora": only positions >= inv_start are adapted (activation-aware
    masking); earlier positions keep index 0 (base weights).
    """
    positions = np.asarray(positions)
    if slot == 0 or kind is None:
        return np.zeros_like(positions, dtype=np.int32)
    if kind == "lora":
        return np.full_like(positions, slot, dtype=np.int32)
    assert kind == "alora", kind
    return np.where(positions >= inv_start, slot, 0).astype(np.int32)


def build_batch_adapter_idx(position_rows: List[np.ndarray],
                            slots: List[int],
                            kinds: List[Optional[str]],
                            inv_starts: List[int]) -> np.ndarray:
    """Batch version (paper Appendix B): one row per running request.

    position_rows: list of (S,) absolute positions per request (padded
    rows allowed — padding positions can be anything; the tokens are
    ignored downstream).  Returns (B, S) int32 adapter indices.
    """
    rows = [
        adapter_index_for_positions(p, s, k, i)
        for p, s, k, i in zip(position_rows, slots, kinds, inv_starts)
    ]
    return np.stack(rows).astype(np.int32)
