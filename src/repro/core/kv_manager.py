"""Paged KV-cache block manager (vLLM-style, host-side metadata).

Physical KV tensors live in a device-side pool owned by the model runner
(``(num_blocks, block_size, kv_heads, head_dim)`` per layer); this module
manages **block identity**: allocation, ref-counting, the hash→block
prefix-cache index, and LRU reuse of freed-but-still-hashed blocks.

vLLM semantics reproduced here (paper §3):

* blocks are ref-counted; multiple requests may share a block;
* a completed request's blocks return to the free pool but **stay in the
  hash index** — an incoming request whose block hashes match may revive
  them (this is what makes automatic prefix caching work across requests);
* eviction happens lazily: allocating a fresh block pops the
  least-recently-freed block and unregisters its hash.

Because hashing is *base-aligned* (``repro.core.block_hash``), blocks
prefilled by the base model and pre-activation blocks prefilled by any
aLoRA adapter share hash values — cross-model reuse needs no further
mechanism here.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.block_hash import BlockHash


class OutOfBlocks(Exception):
    pass


@dataclass
class BlockMeta:
    ref: int = 0
    hash: Optional[BlockHash] = None


class BlockManager:
    """Identity/refcount/prefix-index manager over a fixed block pool."""

    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.meta: List[BlockMeta] = [BlockMeta() for _ in range(num_blocks)]
        # free blocks in LRU order (least recently freed first)
        self.free: "OrderedDict[int, None]" = OrderedDict(
            (i, None) for i in range(num_blocks))
        self.index: Dict[BlockHash, int] = {}
        # stats
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- queries -------------------------------------------------------------
    def num_free(self) -> int:
        return len(self.free)

    def lookup(self, h: BlockHash) -> Optional[int]:
        """Find a cached block by hash WITHOUT acquiring it."""
        return self.index.get(h)

    # -- acquisition ---------------------------------------------------------
    def acquire_cached(self, h: BlockHash) -> Optional[int]:
        """Acquire (ref+1) the block with hash ``h`` if present; revives
        freed blocks from the pool.  Counts a hit/miss."""
        bid = self.index.get(h)
        if bid is None:
            self.misses += 1
            return None
        self.acquire(bid)
        self.hits += 1
        return bid

    def acquire(self, bid: int) -> int:
        """Ref+1 a specific block by id (reviving it from the free pool
        if needed) — dedup remapping onto a canonical block."""
        if self.meta[bid].ref == 0:
            self.free.pop(bid, None)
        self.meta[bid].ref += 1
        return bid

    def allocate(self) -> int:
        """Allocate a fresh (unhashed) block, evicting LRU if needed."""
        if not self.free:
            raise OutOfBlocks("KV-cache pool exhausted")
        bid, _ = self.free.popitem(last=False)
        m = self.meta[bid]
        if m.hash is not None:                 # evict stale hash entry
            if self.index.get(m.hash) == bid:
                del self.index[m.hash]
            self.evictions += 1
        self.meta[bid] = BlockMeta(ref=1, hash=None)
        return bid

    # -- registration --------------------------------------------------------
    def register(self, bid: int, h: BlockHash) -> int:
        """Register a fully-written block under hash ``h``.

        If another live block already owns this hash, keep the existing
        mapping (dedup) and return the canonical block id.
        """
        existing = self.index.get(h)
        if existing is not None and existing != bid:
            return existing
        self.index[h] = bid
        self.meta[bid].hash = h
        return bid

    # -- release -------------------------------------------------------------
    def release(self, bid: int) -> None:
        m = self.meta[bid]
        assert m.ref > 0, f"double free of block {bid}"
        m.ref -= 1
        if m.ref == 0:
            # back to pool; hash entry stays until eviction (vLLM semantics)
            self.free[bid] = None

    def release_all(self, bids: List[int]) -> None:
        for b in bids:
            self.release(b)

    # -- stats ---------------------------------------------------------------
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0
