"""The paper's contribution: cross-model KV-cache reuse with Activated
LoRA — base-aligned block hashing, activation-aware masking, paged block
management, and the cross-model prefix cache (incl. the beyond-paper SSM
state-snapshot extension)."""
from repro.core.activation_mask import (  # noqa: F401
    adapter_index_for_positions,
    build_batch_adapter_idx,
    find_invocation_start,
)
from repro.core.alora import (  # noqa: F401
    PAPER_ALORA_RANK,
    PAPER_LORA_RANK,
    AdapterSpec,
    adapter_param_specs,
    adapter_rank_of,
    init_adapter_weights,
    pad_adapter_rank,
    per_layer_adapters,
    stack_adapters,
    zero_adapter_weights,
)
from repro.core.block_hash import (  # noqa: F401
    AdapterKey,
    block_extra,
    hash_block,
    request_block_hashes,
)
from repro.core.kv_manager import BlockManager, OutOfBlocks  # noqa: F401
from repro.core.prefix_cache import MatchResult, PrefixCache  # noqa: F401
