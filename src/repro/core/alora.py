"""Activated-LoRA adapter weights (and the vanilla-LoRA baseline).

Adapter weights mirror the model's segment stacking
(``repro.models.model.period_segments``): for each attention segment a
pytree {"aq","bq","ak","bk","av","bv"} with leading (repeats, count)
layer dims; for each SSM segment {"a","b"} targeting the SSM input
projection — B spans the full fused [z|xBC|dt] in_dim and the delta is
sliced onto the split in_z/in_xbc/in_dt matmuls (the beyond-paper SSM
extension).  ``stack_adapters`` inserts the **zero
adapter at index 0** and stacks the active set along a new adapter axis —
the layout consumed by ``repro.models.layers.lora_delta``.

Numerically, aLoRA and vanilla LoRA weights are identical objects; the
difference is *where they apply* (activation-aware adapter indices,
``repro.core.activation_mask``) and *how their blocks hash*
(``repro.core.block_hash``).  Per the paper §4.1, adapter VALUES don't
affect serving speed — benchmark adapters are random; rank defaults are
the paper's (LoRA r=8, aLoRA r=32).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, SSM, ModelConfig
from repro.models import ssm as ssm_lib
from repro.models.layers import dtype_of
from repro.models.model import period_segments

Params = Dict[str, Any]

PAPER_LORA_RANK = 8
PAPER_ALORA_RANK = 32


@dataclass(frozen=True)
class AdapterSpec:
    """A registered adapter.

    ``invocation_tokens`` present ⇒ Activated LoRA (the engine identifies
    aLoRA requests by this field, paper §3); absent ⇒ vanilla LoRA.
    """
    name: str
    rank: int
    invocation_tokens: Optional[Tuple[int, ...]] = None

    @property
    def kind(self) -> str:
        return "alora" if self.invocation_tokens is not None else "lora"


def init_adapter_weights(key, cfg: ModelConfig, rank: int,
                         zero_b: bool = False) -> Params:
    """One adapter's weights, segment-stacked to match the model params."""
    dtype = dtype_of(cfg)
    repeats, segs = period_segments(cfg)
    H, KV, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    out: Params = {}
    a_std = 1.0 / math.sqrt(d)
    b_std = 0.0 if zero_b else 0.02 / math.sqrt(rank)

    def mk(key, shape, std):
        if std == 0.0:
            return jnp.zeros(shape, dtype)
        return (jax.random.normal(key, shape) * std).astype(dtype)

    for si, (kind, count) in enumerate(segs):
        n = repeats * count
        ks = jax.random.split(jax.random.fold_in(key, si), 6 * n)
        if kind == ATTN:
            def stack(j, shape, std):
                return jnp.stack([mk(ks[6 * i + j], shape, std)
                                  for i in range(n)]).reshape(
                    (repeats, count) + shape)
            out[f"seg{si}"] = {
                "aq": stack(0, (d, rank), a_std),
                "bq": stack(1, (rank, H * hd), b_std),
                "ak": stack(2, (d, rank), a_std),
                "bk": stack(3, (rank, KV * hd), b_std),
                "av": stack(4, (d, rank), a_std),
                "bv": stack(5, (rank, KV * hd), b_std),
            }
        else:
            in_dim = ssm_lib.ssm_dims(cfg)[0] * 2 \
                + 2 * cfg.ssm.ngroups * cfg.ssm.state_dim \
                + ssm_lib.ssm_dims(cfg)[1]
            def stack2(j, shape, std):
                return jnp.stack([mk(ks[6 * i + j], shape, std)
                                  for i in range(n)]).reshape(
                    (repeats, count) + shape)
            out[f"seg{si}"] = {
                "a": stack2(0, (d, rank), a_std),
                "b": stack2(1, (rank, in_dim), b_std),
            }
    return out


def zero_adapter_weights(cfg: ModelConfig, rank: int) -> Params:
    """The index-0 'no adapter' entry (all zeros ⇒ delta is exactly 0)."""
    w = jax.eval_shape(
        lambda k: init_adapter_weights(k, cfg, rank), jax.random.key(0))
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), w)


def adapter_rank_of(weights: Params) -> int:
    """Read an adapter's rank off its first segment's A matrix."""
    seg = weights[sorted(weights)[0]]
    a = seg["aq"] if "aq" in seg else seg["a"]
    return a.shape[-1]


def pad_adapter_rank(weights: Params, target_rank: int) -> Params:
    """Zero-extend an adapter's rank dimension to ``target_rank``.

    The **zero-block invariant**: for every A/B pair the delta is
    ``x @ A @ B``; appending zero *columns* to A (axis -1) and matching
    zero *rows* to B (axis -2) leaves the product bit-identical —
    ``x @ [A|0] @ [B;0] == x @ A @ B``.  This is what lets heterogeneous
    ranks share one bucketed slot shape in the device-resident adapter
    pool without perturbing aLoRA semantics (pre-activation tokens still
    see an exact zero delta through adapter index 0).
    """
    r = adapter_rank_of(weights)
    if r == target_rank:
        return weights
    assert r < target_rank, (r, target_rank)

    def pad(path_key: str, leaf):
        pads = [(0, 0)] * leaf.ndim
        if path_key.startswith("a"):            # A: (..., d, r) — pad cols
            pads[-1] = (0, target_rank - r)
        else:                                   # B: (..., r, out) — pad rows
            assert path_key.startswith("b"), path_key
            pads[-2] = (0, target_rank - r)
        return jnp.pad(leaf, pads)

    return {seg: {k: pad(k, v) for k, v in leaves.items()}
            for seg, leaves in weights.items()}


def stack_adapters(cfg: ModelConfig, adapters: List[Params],
                   rank: int) -> Params:
    """Stack [zero, ad_1, ..., ad_n] along a new adapter axis.

    ``rank`` is the stacked (slot-bucket) rank: adapters of any rank
    ≤ ``rank`` are zero-extended into the bucket shape first
    (``pad_adapter_rank`` — exact, see the zero-block invariant there),
    so heterogeneous-rank adapter sets stack into one tensor.

    Output leaves: (repeats, count, n+1, ...) — sliced per layer inside
    the model scan, then indexed per token by ``lora_delta``.
    """
    all_ads = [zero_adapter_weights(cfg, rank)] + \
        [pad_adapter_rank(w, rank) for w in adapters]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=2), *all_ads)


def per_layer_adapters(cfg: ModelConfig, stacked: Params) -> List[Params]:
    """Slice a segment-stacked adapter tree into the per-layer list the
    serving runner (and the adapter pool) consume: one pytree per model
    layer, leaves keeping their leading adapter axis."""
    out: List[Params] = []
    repeats, segs = period_segments(cfg)
    for r in range(repeats):
        for si, (kind, count) in enumerate(segs):
            seg = stacked[f"seg{si}"]
            for c in range(count):
                out.append(jax.tree.map(lambda a: a[r, c], seg))
    return out


def adapter_param_specs(cfg: ModelConfig, rank: int, n_adapters: int
                        ) -> Params:
    """Abstract stacked-adapter tree for dry-run lowering."""
    one = jax.eval_shape(
        lambda k: init_adapter_weights(k, cfg, rank), jax.random.key(0))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape[:2] + (n_adapters + 1,) + s.shape[2:], s.dtype), one)
