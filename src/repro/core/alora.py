"""Activated-LoRA adapter weights (and the vanilla-LoRA baseline).

Adapter weights mirror the model's segment stacking
(``repro.models.model.period_segments``): for each attention segment a
pytree {"aq","bq","ak","bk","av","bv"} with leading (repeats, count)
layer dims; for each SSM segment {"a","b"} targeting ``in_proj`` (the
beyond-paper SSM extension).  ``stack_adapters`` inserts the **zero
adapter at index 0** and stacks the active set along a new adapter axis —
the layout consumed by ``repro.models.layers.lora_delta``.

Numerically, aLoRA and vanilla LoRA weights are identical objects; the
difference is *where they apply* (activation-aware adapter indices,
``repro.core.activation_mask``) and *how their blocks hash*
(``repro.core.block_hash``).  Per the paper §4.1, adapter VALUES don't
affect serving speed — benchmark adapters are random; rank defaults are
the paper's (LoRA r=8, aLoRA r=32).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, SSM, ModelConfig
from repro.models import ssm as ssm_lib
from repro.models.layers import dtype_of
from repro.models.model import period_segments

Params = Dict[str, Any]

PAPER_LORA_RANK = 8
PAPER_ALORA_RANK = 32


@dataclass(frozen=True)
class AdapterSpec:
    """A registered adapter.

    ``invocation_tokens`` present ⇒ Activated LoRA (the engine identifies
    aLoRA requests by this field, paper §3); absent ⇒ vanilla LoRA.
    """
    name: str
    rank: int
    invocation_tokens: Optional[Tuple[int, ...]] = None

    @property
    def kind(self) -> str:
        return "alora" if self.invocation_tokens is not None else "lora"


def init_adapter_weights(key, cfg: ModelConfig, rank: int,
                         zero_b: bool = False) -> Params:
    """One adapter's weights, segment-stacked to match the model params."""
    dtype = dtype_of(cfg)
    repeats, segs = period_segments(cfg)
    H, KV, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    out: Params = {}
    a_std = 1.0 / math.sqrt(d)
    b_std = 0.0 if zero_b else 0.02 / math.sqrt(rank)

    def mk(key, shape, std):
        if std == 0.0:
            return jnp.zeros(shape, dtype)
        return (jax.random.normal(key, shape) * std).astype(dtype)

    for si, (kind, count) in enumerate(segs):
        n = repeats * count
        ks = jax.random.split(jax.random.fold_in(key, si), 6 * n)
        if kind == ATTN:
            def stack(j, shape, std):
                return jnp.stack([mk(ks[6 * i + j], shape, std)
                                  for i in range(n)]).reshape(
                    (repeats, count) + shape)
            out[f"seg{si}"] = {
                "aq": stack(0, (d, rank), a_std),
                "bq": stack(1, (rank, H * hd), b_std),
                "ak": stack(2, (d, rank), a_std),
                "bk": stack(3, (rank, KV * hd), b_std),
                "av": stack(4, (d, rank), a_std),
                "bv": stack(5, (rank, KV * hd), b_std),
            }
        else:
            in_dim = ssm_lib.ssm_dims(cfg)[0] * 2 \
                + 2 * cfg.ssm.ngroups * cfg.ssm.state_dim \
                + ssm_lib.ssm_dims(cfg)[1]
            def stack2(j, shape, std):
                return jnp.stack([mk(ks[6 * i + j], shape, std)
                                  for i in range(n)]).reshape(
                    (repeats, count) + shape)
            out[f"seg{si}"] = {
                "a": stack2(0, (d, rank), a_std),
                "b": stack2(1, (rank, in_dim), b_std),
            }
    return out


def zero_adapter_weights(cfg: ModelConfig, rank: int) -> Params:
    """The index-0 'no adapter' entry (all zeros ⇒ delta is exactly 0)."""
    w = jax.eval_shape(
        lambda k: init_adapter_weights(k, cfg, rank), jax.random.key(0))
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), w)


def stack_adapters(cfg: ModelConfig, adapters: List[Params],
                   rank: int) -> Params:
    """Stack [zero, ad_1, ..., ad_n] along a new adapter axis.

    Output leaves: (repeats, count, n+1, ...) — sliced per layer inside
    the model scan, then indexed per token by ``lora_delta``.
    """
    all_ads = [zero_adapter_weights(cfg, rank)] + list(adapters)
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=2), *all_ads)


def adapter_param_specs(cfg: ModelConfig, rank: int, n_adapters: int
                        ) -> Params:
    """Abstract stacked-adapter tree for dry-run lowering."""
    one = jax.eval_shape(
        lambda k: init_adapter_weights(k, cfg, rank), jax.random.key(0))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape[:2] + (n_adapters + 1,) + s.shape[2:], s.dtype), one)
