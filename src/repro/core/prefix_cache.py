"""Cross-model prefix cache: base-aligned block matching + (beyond-paper)
SSM state-snapshot matching.

``PrefixCache`` sits between the scheduler and the block pool:

* ``match_and_acquire(tokens, adapter)`` walks the request's chained
  block hashes and acquires every leading block already in the pool —
  because hashing is base-aligned, an aLoRA request transparently matches
  blocks prefilled by the base model (and vice versa; paper Fig. 3/4).

* For SSM / hybrid architectures it additionally matches **state
  snapshots**: the recurrent state at block-aligned boundaries, keyed by
  the same chained hash.  The deepest boundary with BOTH a snapshot and
  full KV-block coverage determines the reuse length (pure-SSM archs have
  no KV constraint; pure-attention archs no snapshot constraint).  This
  extends the paper's technique to the Mamba-style models it explicitly
  left out.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.core.block_hash import (AdapterKey, BlockHash,
                                   request_block_hashes)
from repro.core.kv_manager import BlockManager


@dataclass
class MatchResult:
    n_tokens: int                      # reusable prefix length (tokens)
    kv_blocks: List[int] = field(default_factory=list)
    state_slot: Optional[int] = None   # SSM snapshot slot at the boundary
    hashes: List[BlockHash] = field(default_factory=list)  # all full-block
    #                                   hashes of the request (for later
    #                                   registration as blocks fill)


class PrefixCache:
    def __init__(self, *, block_size: int,
                 kv_manager: Optional[BlockManager] = None,
                 state_manager: Optional[BlockManager] = None):
        assert kv_manager is not None or state_manager is not None
        self.block_size = block_size
        self.kv = kv_manager
        self.state = state_manager

    # ------------------------------------------------------------------
    def match_and_acquire(self, tokens: Sequence[int],
                          adapter: Optional[AdapterKey],
                          salt: tuple = ()) -> MatchResult:
        bs = self.block_size
        hashes = request_block_hashes(tokens, bs, adapter, salt)

        # longest run of cached KV blocks
        kv_blocks: List[int] = []
        if self.kv is not None:
            for h in hashes:
                bid = self.kv.acquire_cached(h)
                if bid is None:
                    break
                kv_blocks.append(bid)
            kv_depth = len(kv_blocks)
        else:
            kv_depth = len(hashes)

        # deepest state snapshot at/below kv_depth
        state_slot = None
        state_depth = 0
        if self.state is not None:
            for i in range(kv_depth, 0, -1):
                if self.state.lookup(hashes[i - 1]) is not None:
                    state_slot = self.state.acquire_cached(hashes[i - 1])
                    state_depth = i
                    break
            depth = state_depth
        else:
            depth = kv_depth

        # trim over-acquired KV blocks beyond the usable boundary
        if self.kv is not None and depth < len(kv_blocks):
            for bid in kv_blocks[depth:]:
                self.kv.release(bid)
            kv_blocks = kv_blocks[:depth]

        return MatchResult(n_tokens=depth * bs, kv_blocks=kv_blocks,
                           state_slot=state_slot, hashes=hashes)

    # ------------------------------------------------------------------
    def probe(self, tokens: Sequence[int], adapter: Optional[AdapterKey],
              salt: tuple = ()) -> int:
        """Non-acquiring locality probe: the reusable prefix length (in
        tokens) ``match_and_acquire`` WOULD return for this request,
        without touching refcounts or the hit/miss counters.  This is the
        serving router's placement primitive — it may probe every replica
        per admission, so the probe must not perturb cache state or skew
        the hit-rate statistics the benchmarks report.
        """
        bs = self.block_size
        hashes = request_block_hashes(tokens, bs, adapter, salt)
        kv_depth = 0
        if self.kv is not None:
            for h in hashes:
                if self.kv.lookup(h) is None:
                    break
                kv_depth += 1
        else:
            kv_depth = len(hashes)
        if self.state is not None:
            # reuse boundary needs a state snapshot at/below KV coverage
            for i in range(kv_depth, 0, -1):
                if self.state.lookup(hashes[i - 1]) is not None:
                    return i * bs
            return 0
        return kv_depth * bs

    # ------------------------------------------------------------------
    def register_kv_block(self, h: BlockHash, bid: int) -> int:
        """Register a just-filled KV block; returns canonical block id."""
        assert self.kv is not None
        return self.kv.register(bid, h)

    def register_state(self, h: BlockHash, slot: int) -> int:
        assert self.state is not None
        return self.state.register(slot, h)

    # ------------------------------------------------------------------
    def hit_rate(self) -> float:
        mgr = self.kv if self.kv is not None else self.state
        return mgr.hit_rate()
