"""Serving launcher: run the paged serving engine with batched requests.

This is the end-to-end serving driver: it builds a reduced model of the
selected architecture, registers aLoRA (and optionally vanilla-LoRA
baseline) adapters, replays a batch of multi-turn base→adapter requests
through the engine, and prints per-stage latency + cache-hit metrics.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3.2-8b \
      --requests 8 --prompt-len 128
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.alora import (PAPER_ALORA_RANK, PAPER_LORA_RANK,
                              AdapterSpec, init_adapter_weights)
from repro.models import init_params
from repro.serving import Engine, EngineConfig, speedup_table
from repro.serving import pipelines as P


def build_engine(cfg, params, kind: str, n_adapters: int = 1,
                 engine_cfg: EngineConfig = EngineConfig()) -> Engine:
    rank = PAPER_ALORA_RANK if kind == "alora" else PAPER_LORA_RANK
    adapters = []
    for i in range(n_adapters):
        inv = tuple(range(3, 6)) if kind == "alora" else None
        spec = AdapterSpec(f"intrinsic{i}", rank=rank,
                           invocation_tokens=inv)
        w = init_adapter_weights(jax.random.key(100 + i), cfg, rank)
        adapters.append((spec, w))
    return Engine(cfg, params, adapters=adapters, engine_cfg=engine_cfg)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3.2-8b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--eval-len", type=int, default=16)
    ap.add_argument("--adapters", type=int, default=1)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    print(f"serving reduced {cfg.name} ({cfg.arch_type})")
    params = init_params(jax.random.key(0), cfg)

    results = {}
    for kind in ("lora", "alora"):
        # warmup pass compiles all jit buckets, then a fresh engine
        # measures with cold caches but warm code
        for seed in (123, 0):
            eng = build_engine(cfg, params, kind, args.adapters)
            names = [f"intrinsic{i}" for i in range(args.adapters)]
            res = P.base_adapter(
                eng, adapter_names=names, prompt_len=args.prompt_len,
                gen_len=args.gen_len, eval_len=args.eval_len,
                batch=args.requests, feed_back_to_base=True, seed=seed)
        results[kind] = (eng, res)
        for stage in ("base", "eval", "final"):
            m = res.stage_metrics(eng, stage)
            print(f"  {kind:5s} {stage:5s} e2e={m.means['e2e']:.3f}s "
                  f"ttft={m.means['ttft']:.4f}s "
                  f"prefill={m.means['prefill']:.4f}s "
                  f"decode={m.means['decode']:.3f}s "
                  f"hit={m.means['cache_hit_frac']:.2f}")

    sp = speedup_table(results["lora"][1].stage_metrics(
        results["lora"][0], "eval"),
        results["alora"][1].stage_metrics(results["alora"][0], "eval"))
    print("adapter-evaluation speedups (LoRA baseline / aLoRA):",
          {k: round(v, 2) for k, v in sp.items()})


if __name__ == "__main__":
    main()
