"""Serving launcher: run the paged serving engine with batched requests.

This is the end-to-end serving driver: it builds a reduced model of the
selected architecture, registers aLoRA (and optionally vanilla-LoRA
baseline) adapters, replays a batch of multi-turn base→adapter requests
through the engine, and prints per-stage latency + cache-hit metrics.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3.2-8b \
      --requests 8 --prompt-len 128

``--replicas N`` scales the same workload out over N in-process engine
replicas behind the cache-affinity router (``serving/router.py``) —
each replica gets its own pools, prefix cache and adapter slots, and
every submission is placed by aLoRA-aligned prefix locality.
``--route {affinity,round_robin}`` selects the placement policy
(round_robin is the blind baseline); with ``--replicas 1`` the router
tier is skipped entirely and the engine is driven directly.

``--trace-out FILE`` exports the aLoRA run's trace rings (every
replica's, plus the router's, when a fleet ran) as a Perfetto timeline
— load it at https://ui.perfetto.dev to see submit/retire overlap and
per-request queue→prefill→decode lifecycles.  ``--metrics-out FILE``
writes the same run's counters as a Prometheus text snapshot.  Schema:
``docs/observability.md``.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.alora import (PAPER_ALORA_RANK, PAPER_LORA_RANK,
                              AdapterSpec, init_adapter_weights)
from repro.models import init_params
from repro.obs import prometheus_text, write_perfetto
from repro.serving import Engine, EngineConfig, fmt_speedups, speedup_table
from repro.serving import pipelines as P
from repro.serving.router import POLICIES, Router


def build_engine(cfg, params, kind: str, n_adapters: int = 1,
                 engine_cfg: EngineConfig = EngineConfig(),
                 replicas: int = 1, route: str = "affinity"):
    """One engine, or — with ``replicas > 1`` — a Router over N
    identically-built replicas (drop-in for the pipeline drivers)."""
    rank = PAPER_ALORA_RANK if kind == "alora" else PAPER_LORA_RANK
    adapters = []
    for i in range(n_adapters):
        inv = tuple(range(3, 6)) if kind == "alora" else None
        spec = AdapterSpec(f"intrinsic{i}", rank=rank,
                           invocation_tokens=inv)
        w = init_adapter_weights(jax.random.key(100 + i), cfg, rank)
        adapters.append((spec, w))

    def mk() -> Engine:
        return Engine(cfg, params, adapters=adapters,
                      engine_cfg=engine_cfg)

    if replicas <= 1:
        return mk()
    return Router([mk() for _ in range(replicas)], policy=route)


def collect_tracers(eng):
    """Every tracer a serving tier carries: per-replica engine tracers
    plus the router's own when a fleet ran."""
    if isinstance(eng, Router):
        return [e.tracer for e in eng.replicas] + [eng.tracer]
    return [eng.tracer]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3.2-8b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--eval-len", type=int, default=16)
    ap.add_argument("--adapters", type=int, default=1)
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the affinity router "
                         "(1 = no router tier)")
    ap.add_argument("--route", choices=POLICIES, default="affinity",
                    help="placement policy with --replicas > 1")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write the aLoRA run's Perfetto timeline JSON "
                         "here (load at https://ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the aLoRA run's counters here in the "
                         "Prometheus text exposition format")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    tier = f" x{args.replicas} replicas ({args.route})" \
        if args.replicas > 1 else ""
    print(f"serving reduced {cfg.name} ({cfg.arch_type}){tier}")
    params = init_params(jax.random.key(0), cfg)

    results = {}
    for kind in ("lora", "alora"):
        # warmup pass compiles all jit buckets, then a fresh engine
        # measures with cold caches but warm code
        for seed in (123, 0):
            eng = build_engine(cfg, params, kind, args.adapters,
                               replicas=args.replicas, route=args.route)
            names = [f"intrinsic{i}" for i in range(args.adapters)]
            res = P.base_adapter(
                eng, adapter_names=names, prompt_len=args.prompt_len,
                gen_len=args.gen_len, eval_len=args.eval_len,
                batch=args.requests, feed_back_to_base=True, seed=seed)
        results[kind] = (eng, res)
        for stage in ("base", "eval", "final"):
            m = res.stage_metrics(eng, stage)
            print(f"  {kind:5s} {stage:5s} e2e={m.means['e2e']:.3f}s "
                  f"ttft={m.means['ttft']:.4f}s "
                  f"prefill={m.means['prefill']:.4f}s "
                  f"decode={m.means['decode']:.3f}s "
                  f"hit={m.means['cache_hit_frac']:.2f}")
        if isinstance(eng, Router):
            per = [sum(1 for p in eng.placements if p.replica == i)
                   for i in range(len(eng.replicas))]
            print(f"  {kind:5s} fleet hit={eng.kv_hit_rate():.2f} "
                  f"placements/replica={per}")

    sp = speedup_table(results["lora"][1].stage_metrics(
        results["lora"][0], "eval"),
        results["alora"][1].stage_metrics(results["alora"][0], "eval"))
    print("adapter-evaluation speedups (LoRA baseline / aLoRA):",
          fmt_speedups(sp))

    if args.trace_out or args.metrics_out:
        trs = collect_tracers(results["alora"][0])
        if args.trace_out:
            write_perfetto(args.trace_out, trs)
            print(f"wrote Perfetto timeline -> {args.trace_out} "
                  "(load at https://ui.perfetto.dev)")
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                f.write(prometheus_text(trs))
            print(f"wrote Prometheus counters -> {args.metrics_out}")


if __name__ == "__main__":
    main()
