"""Distributed step functions + abstract input specs for every
(architecture × input shape) combination.

Three lowered entry points, matching the assigned input shapes:

* ``train_step``   (train_4k)     — loss/backward/AdamW, remat, ZeRO-1
* ``prefill_step`` (prefill_32k)  — full-sequence prefill returning the
  last-token logits and the KV caches / SSM states, with an aLoRA
  adapter + per-token adapter indices in the graph (the paper's
  activation-aware masking lowers with the model)
* ``decode_step``  (decode_32k, long_500k) — one token against a dense
  KV cache (ring-buffer for sliding-window archs), aLoRA included

``input_specs`` returns ShapeDtypeStructs only — nothing here allocates.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, InputShape
from repro.core.alora import PAPER_ALORA_RANK, adapter_param_specs
from repro.distributed import sharding as sh
from repro.launch.mesh import batch_axes_of
from repro.models import model as M
from repro.models.model import Runtime
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import (TrainState, init_train_state,
                                       make_train_step)

LONG_CONTEXT_WINDOW = 8192
N_ADAPTERS = 1          # adapters stacked into the lowered graph


def make_runtime(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                 **overrides) -> Runtime:
    # long_500k must be sub-quadratic/cache-bounded: pure-SSM archs are
    # natively so; archs with a model-card window (starcoder2) keep it;
    # all other attention layers get the sliding-window variant
    # (DESIGN.md §4).
    window = 0
    if shape.name == "long_500k" and cfg.arch_type != "ssm" \
            and not cfg.sliding_window:
        window = LONG_CONTEXT_WINDOW
    kw = dict(
        moe_impl="expert_parallel" if cfg.moe is not None else
        "masked_dense",
        mesh=mesh,
        batch_axes=batch_axes_of(mesh),
        model_axis="model",
        remat=(shape.mode == "train"),
        shard_activations=True,
        window_override=window,
        q_block=512,
        kv_block=1024,
    )
    kw.update(overrides)
    return Runtime(**kw)


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape,
                rt: Runtime) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    out: Dict[str, Any] = {}
    if shape.mode == "train":
        out["batch"] = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
            "mask": _sds((B, S), jnp.float32),
        }
        if cfg.frontend == "vision":
            out["batch"]["extra_embeds"] = _sds((B, cfg.num_patches,
                                                 cfg.d_model), dt)
        elif cfg.frontend == "audio":
            out["batch"]["extra_embeds"] = _sds((B, cfg.encoder_seq_len,
                                                 cfg.d_model), dt)
        return out
    if shape.mode == "prefill":
        out["tokens"] = _sds((B, S), jnp.int32)
        out["adapter_idx"] = _sds((B, S), jnp.int32)
        if cfg.frontend == "vision":
            out["extra_embeds"] = _sds((B, cfg.num_patches, cfg.d_model),
                                       dt)
        elif cfg.frontend == "audio":
            out["extra_embeds"] = _sds((B, cfg.encoder_seq_len,
                                        cfg.d_model), dt)
        return out
    # decode: one token against an S-token cache
    out["token"] = _sds((B, 1), jnp.int32)
    out["adapter_idx"] = _sds((B, 1), jnp.int32)
    out["cache_len"] = _sds((), jnp.int32)
    out["caches"] = jax.eval_shape(
        lambda: M.init_decode_caches(cfg, B, S, rt))
    return out


def adapter_specs(cfg: ModelConfig):
    return adapter_param_specs(cfg, PAPER_ALORA_RANK, N_ADAPTERS)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def make_prefill_fn(cfg: ModelConfig, rt: Runtime):
    def prefill_step(params, adapters, tokens, adapter_idx,
                     extra_embeds=None):
        hidden, _, caches = M.forward_full(
            params, cfg, tokens, rt, adapters=adapters,
            adapter_idx=adapter_idx, extra_embeds=extra_embeds,
            return_caches=True)
        logits = M.logits_for(params, cfg, hidden[:, -1:])
        return logits, caches
    return prefill_step


def make_decode_fn(cfg: ModelConfig, rt: Runtime):
    def decode_fn(params, adapters, token, caches, cache_len, adapter_idx):
        return M.decode_step(params, cfg, token, caches, cache_len, rt,
                             adapters=adapters, adapter_idx=adapter_idx)
    return decode_fn


def make_train_fn(cfg: ModelConfig, rt: Runtime,
                  ocfg: AdamWConfig = AdamWConfig()):
    return make_train_step(cfg, ocfg, rt)


# ---------------------------------------------------------------------------
# sharding assembly
# ---------------------------------------------------------------------------
@dataclass
class LoweredSpec:
    fn: Any
    args: tuple              # ShapeDtypeStructs (jit-able)
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


def build_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
               rt: Optional[Runtime] = None,
               zero1: bool = True) -> LoweredSpec:
    """Assemble (fn, abstract args, shardings) for one (arch × shape)."""
    rt = rt or make_runtime(cfg, mesh, shape)
    b_axes = rt.batch_axes
    params_shape = M.param_specs(cfg)
    if rt.context_parallel:
        assert cfg.arch_type in ("dense",), \
            "context-parallel prefill is implemented for dense archs"
        pspecs = sh.fsdp_param_specs_tree(cfg, params_shape, mesh)
    else:
        pspecs = sh.param_specs_tree(cfg, params_shape)
    ins = input_specs(cfg, shape, rt)

    if shape.mode == "train":
        fn = make_train_fn(cfg, rt)
        state_shape = jax.eval_shape(init_train_state, params_shape)
        mu_specs = sh.param_specs_tree(cfg, state_shape.opt.mu)
        nu_specs = sh.param_specs_tree(cfg, state_shape.opt.nu)
        if zero1:
            mu_specs = sh.zero1_specs(mu_specs, state_shape.opt.mu, mesh)
            nu_specs = sh.zero1_specs(nu_specs, state_shape.opt.nu, mesh)
        state_specs = TrainState(
            params=pspecs,
            opt=type(state_shape.opt)(step=P(), mu=mu_specs, nu=nu_specs))
        bspecs = {k: sh.batch_specs(b_axes)[k] for k in ins["batch"]}
        args = (state_shape, ins["batch"])
        in_sh = (sh.to_named(state_specs, mesh), sh.to_named(bspecs, mesh))
        return LoweredSpec(fn, args, in_sh,
                           (sh.to_named(state_specs, mesh), None),
                           donate_argnums=(0,))

    ad_shape = adapter_specs(cfg)
    ad_specs = sh.adapter_specs_tree(cfg, ad_shape)
    if shape.mode == "prefill":
        fn = make_prefill_fn(cfg, rt)
        args = [params_shape, ad_shape, ins["tokens"], ins["adapter_idx"]]
        in_specs = [pspecs, ad_specs, P(b_axes, None), P(b_axes, None)]
        if "extra_embeds" in ins:
            args.append(ins["extra_embeds"])
            in_specs.append(P(b_axes, None, None))
        caches_shape = jax.eval_shape(fn, *args)[1]
        cache_sp = sh.cache_specs_tree(cfg, caches_shape, mesh, b_axes)
        logits_sp = P(b_axes, None, "model")
        return LoweredSpec(fn, tuple(args),
                           tuple(sh.to_named(s, mesh) for s in in_specs),
                           (sh.to_named(logits_sp, mesh),
                            sh.to_named(cache_sp, mesh)))

    # decode
    fn = make_decode_fn(cfg, rt)
    caches_shape = ins["caches"]
    bsh = shape.global_batch > 1
    cache_sp = sh.cache_specs_tree(cfg, caches_shape, mesh, b_axes,
                                   batch_shardable=bsh)
    tok_sp = P(b_axes, None) if bsh else P(None, None)
    args = (params_shape, ad_shape, ins["token"], caches_shape,
            ins["cache_len"], ins["adapter_idx"])
    in_specs = (pspecs, ad_specs, tok_sp, cache_sp, P(), tok_sp)
    logits_sp = P(b_axes, None, "model") if bsh else P(None, None, "model")
    return LoweredSpec(fn, args,
                       tuple(sh.to_named(s, mesh) for s in in_specs),
                       (sh.to_named(logits_sp, mesh),
                        sh.to_named(cache_sp, mesh)),
                       donate_argnums=(3,))
