import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes and extract memory/cost/collective analysis.

This is the hardware-free proof that the distribution config is coherent:
a sharding mismatch, a compile-time OOM, or an unsupported collective
fails HERE.  The roofline table (EXPERIMENTS.md §Roofline) is derived
from the artifacts this script writes.

Usage:
  python -m repro.launch.dryrun --arch stablelm-12b --shape train_4k
  python -m repro.launch.dryrun --all --mesh pod --out results/dryrun
  python -m repro.launch.dryrun --arch mamba2-2.7b --shape long_500k \
      --mesh multipod
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch.hlo_analysis import (CollectiveStats, Roofline,
                                       model_flops_for, parse_collectives,
                                       roofline_from_compiled)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step, make_runtime
from repro.models.model import period_segments


def scaled_config(cfg, r: int):
    """Config with r periods of layers (for unrolled cost extrapolation)."""
    repeats, segs = period_segments(cfg)
    period = cfg.num_layers // repeats
    kw = {"num_layers": r * period}
    if cfg.layer_pattern is not None:
        kw["layer_pattern"] = cfg.layer_pattern[:period] * r
    if cfg.is_encoder_decoder:
        enc_per = cfg.num_encoder_layers // repeats
        kw["num_encoder_layers"] = max(enc_per * r, 1)
    return cfg.replace(**kw)


def _compile_and_cost(cfg, shape, mesh, rt):
    """(per-device flops, bytes, CollectiveStats, compiled) for one cfg."""
    spec = build_step(cfg, shape, mesh, rt=rt)
    with mesh:
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings,
                         donate_argnums=spec.donate_argnums)
        compiled = jitted.lower(*spec.args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    return flops, nbytes, parse_collectives(compiled.as_text()), compiled


def extrapolated_roofline(cfg, shape, mesh, rt, chips) -> Roofline:
    """XLA cost_analysis counts a scanned layer body ONCE regardless of
    trip count, so costs of the full scanned model are understated ~L×.
    We compile UNROLLED 1-period and 2-period variants and extrapolate
    linearly: cost(R) = cost(1) + (R-1) * (cost(2) - cost(1)).

    bf16 correction: the CPU backend legalizes bf16 to f32, exactly
    doubling every byte count (collective result shapes in the compiled
    HLO are f32).  On the TPU target those tensors are bf16, so byte
    terms are halved for bf16 models (fp32 reductions like SSM states
    are slightly under-counted; noted in EXPERIMENTS.md)."""
    repeats, _ = period_segments(cfg)
    rt_u = dataclasses.replace(rt, unroll_layers=True)
    f1, b1, c1, _ = _compile_and_cost(scaled_config(cfg, 1), shape, mesh,
                                      rt_u)
    f2, b2, c2, _ = _compile_and_cost(scaled_config(cfg, 2), shape, mesh,
                                      rt_u)
    R = repeats
    corr = 0.5 if cfg.dtype == "bfloat16" else 1.0
    flops = f1 + (R - 1) * (f2 - f1)
    nbytes = (b1 + (R - 1) * (b2 - b1)) * corr
    coll = CollectiveStats()
    kinds = set(c1.by_kind) | set(c2.by_kind)
    for k in kinds:
        v1, v2 = c1.by_kind.get(k, 0), c2.by_kind.get(k, 0)
        n1, n2 = c1.counts.get(k, 0), c2.counts.get(k, 0)
        coll.by_kind[k] = int((v1 + (R - 1) * (v2 - v1)) * corr)
        coll.counts[k] = int(n1 + (R - 1) * (n2 - n1))
    return Roofline(flops=flops, hbm_bytes=nbytes, collective=coll,
                    chips=chips, model_flops=model_flops_for(cfg, shape))


def run_one(arch: str, shape_name: str, multi_pod: bool,
            rt_overrides=None, verbose: bool = True,
            extrapolate: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False}
    t0 = time.time()
    try:
        rt = make_runtime(cfg, mesh, shape, **(rt_overrides or {}))
        # 1) the REAL artifact: full model, scanned layers — proves the
        #    sharding lowers+compiles and gives the memory analysis
        flops_raw, bytes_raw, coll_raw, compiled = _compile_and_cost(
            cfg, shape, mesh, rt)
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
        # 2) roofline terms from unrolled 1-/2-period extrapolation.
        #    The roofline table is single-pod only (the multi-pod pass
        #    proves the `pod` axis shards) — skip the extra compiles there.
        if multi_pod:
            extrapolate = False
        if extrapolate:
            roof = extrapolated_roofline(cfg, shape, mesh, rt, chips)
        else:
            roof = roofline_from_compiled(compiled, chips,
                                          model_flops_for(cfg, shape))
        rec["roofline"] = roof.summary()
        rec["roofline_raw_scanned"] = {
            "flops_per_device": flops_raw,
            "hbm_bytes_per_device": bytes_raw,
            "collective_result_bytes": coll_raw.total_result_bytes(),
        }
        rec["compile_s"] = round(t_compile, 1)
        rec["ok"] = True
        if verbose:
            r = rec["roofline"]
            print(f"[OK] {arch:24s} {shape_name:12s} {rec['mesh']:8s} "
                  f"compute={r['compute_s']:.3e}s "
                  f"memory={r['memory_s']:.3e}s "
                  f"collective={r['collective_s']:.3e}s "
                  f"dominant={r['dominant']:10s} "
                  f"useful={r['useful_flops_ratio']:.2f} "
                  f"(compile {rec['compile_s']}s)")
            print(f"     temp/device={rec['memory'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"args/device={rec['memory'].get('argument_size_in_bytes', 0)/2**30:.2f}GiB")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {arch} {shape_name} {rec['mesh']}: "
                  f"{rec['error']}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None,
                    help="append JSONL records here")
    ap.add_argument("--set", action="append", default=[],
                    help="Runtime override, e.g. --set flash_remat=true "
                         "--set capacity_factor=1.0 (repeatable) — used "
                         "by the §Perf hillclimbing iterations")
    ap.add_argument("--tag", default=None,
                    help="label recorded with the result (perf variants)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = float(v)

    archs = ASSIGNED_ARCHS if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, mp, rt_overrides=overrides)
                if args.tag:
                    rec["tag"] = args.tag
                    rec["overrides"] = overrides
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
                if args.out:
                    os.makedirs(os.path.dirname(args.out) or ".",
                                exist_ok=True)
                    with open(args.out, "a") as f:
                        rec.pop("traceback", None)
                        f.write(json.dumps(rec) + "\n")
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
