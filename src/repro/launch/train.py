"""Distributed training launcher.

Production (``--dryrun``): lowers/compiles the sharded train_step for the
selected arch on the production mesh (same artifact the multi-pod dry-run
validates).  Local (default): trains the arch's REDUCED variant on real
CPU devices for a few hundred steps on the synthetic pipeline — the
end-to-end driver for the training side of the framework.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3.2-8b \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import init_params
from repro.models.model import Runtime
from repro.training import (AdamWConfig, DataConfig, SyntheticDataset,
                            init_train_state, make_train_step,
                            save_checkpoint)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3.2-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    print(f"training reduced {cfg.name}: {cfg.num_layers}L "
          f"d={cfg.d_model} vocab={cfg.vocab_size}")
    params = init_params(jax.random.key(0), cfg)
    state = init_train_state(params)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                       total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, ocfg, Runtime(), loss_chunk=64))
    ds = SyntheticDataset(DataConfig(vocab_size=cfg.vocab_size,
                                     seq_len=args.seq,
                                     global_batch=args.batch))

    def add_frontend(batch):
        if cfg.frontend == "vision":
            rng = np.random.RandomState(0)
            batch["extra_embeds"] = jnp.asarray(rng.randn(
                args.batch, cfg.num_patches, cfg.d_model) * 0.02,
                jnp.dtype(cfg.dtype))
        elif cfg.frontend == "audio":
            rng = np.random.RandomState(0)
            batch["extra_embeds"] = jnp.asarray(rng.randn(
                args.batch, cfg.encoder_seq_len, cfg.d_model) * 0.02,
                jnp.dtype(cfg.dtype))
        return batch

    t0 = time.time()
    for i in range(args.steps):
        batch = add_frontend({k: jnp.asarray(v)
                              for k, v in ds.batch(i).items()})
        state, stats = step_fn(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(stats['loss']):.4f} "
                  f"ce={float(stats['ce']):.4f} "
                  f"gnorm={float(stats['grad_norm']):.3f} "
                  f"lr={float(stats['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.ckpt:
        save_checkpoint(args.ckpt, state.params, step=args.steps)
        print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
