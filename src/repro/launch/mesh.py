"""Production mesh definitions.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods × 256 chips as (pod=2, data=16, model=16) — the
``pod`` axis carries only data parallelism (gradient all-reduce crosses
the inter-pod DCN; everything bandwidth-hungry stays on-pod).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "run under launch/dryrun.py (sets "
            "--xla_force_host_platform_device_count=512)")
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def batch_axes_of(mesh: jax.sharding.Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Host-scale ``(data, model)`` mesh with the production axis names.

    The default ``(1, 1)`` is the historical 1-device CPU mesh for
    tests/examples; nontrivial shapes (e.g. ``(2, 4)`` for the sharded
    mixed-step equivalence suite) need the process started with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` so enough
    host devices exist BEFORE jax initializes.
    """
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"host mesh (data={data}, model={model}) needs {n} devices, "
            f"found {len(devices)} — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before the first "
            "jax import")
    dev = np.asarray(devices[:n]).reshape(data, model)
    return jax.sharding.Mesh(dev, ("data", "model"))
