"""Compiled-HLO analysis: collective-traffic accounting + roofline terms.

``cost_analysis()`` gives HLO FLOPs and HBM bytes but NOT collective
traffic; we parse the compiled HLO text and sum the result-shape bytes of
every collective op, bucketed by kind.  Wire-byte estimates use standard
ring-algorithm factors on the per-chip shard size.

Roofline terms (TPU v5e):
  compute    = HLO_FLOPs / (chips × 197e12 FLOP/s bf16)
  memory     = HLO_bytes / (chips × 819e9 B/s HBM)
  collective = wire_bytes_per_chip / 50e9 B/s per ICI link
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (~)

# fractional byte widths (s4/u4 pack two elements per byte); keep the
# exact value through accounting and round only at the summary edge
_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# matches e.g.:  %all-gather.3 = bf16[2,1024,128]{2,1,0:T(8,128)} all-gather(
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([0-9,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    # result bytes per collective kind (per-chip shard sizes; fractional
    # for sub-byte dtypes — rounded only at the summary edge below)
    by_kind: Dict[str, float] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)

    def total_result_bytes(self) -> int:
        return int(round(sum(self.by_kind.values())))

    def wire_bytes(self, n_shards: int = 16) -> float:
        """Ring-algorithm wire-traffic estimate per chip."""
        f = (n_shards - 1) / max(n_shards, 1)
        w = 0.0
        for kind, b in self.by_kind.items():
            if kind == "all-reduce":
                w += 2 * f * b          # reduce-scatter + all-gather
            elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
                w += f * b
            else:                        # collective-permute
                w += b
        return w


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for m in _OP_RE.finditer(hlo_text):
        tuple_body, dtype, dims, kind, suffix = m.groups()
        # async pairs (-start/-done) appear twice; count the op once, at
        # its -start line (which carries the transferred result shape)
        if suffix == "-done":
            continue
        if tuple_body is not None:
            nbytes = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(tuple_body))
        else:
            nbytes = _shape_bytes(dtype, dims)
        stats.by_kind[kind] = stats.by_kind.get(kind, 0) + nbytes
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    """cost_analysis() of an SPMD module is PER-DEVICE (the module is the
    per-device program); parsed collective result shapes are per-device
    shards likewise.  All terms below are per-chip seconds."""
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    collective: CollectiveStats = field(default_factory=CollectiveStats)
    chips: int = 256
    model_flops: float = 0.0     # 6·N·D (train) or 2·N·D (inference),
    #                              GLOBAL — divided by chips for the ratio

    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    def collective_s(self, n_shards: int = 16) -> float:
        return self.collective.wire_bytes(n_shards) / ICI_BW

    def dominant(self) -> str:
        terms = {"compute": self.compute_s(), "memory": self.memory_s(),
                 "collective": self.collective_s()}
        return max(terms, key=terms.get)

    def useful_flops_ratio(self) -> float:
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def summary(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_result_bytes":
                self.collective.total_result_bytes(),
            "collective_counts": dict(self.collective.counts),
            "compute_s": self.compute_s(),
            "memory_s": self.memory_s(),
            "collective_s": self.collective_s(),
            "dominant": self.dominant(),
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio(),
        }


def roofline_from_compiled(compiled, chips: int,
                           model_flops: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    return Roofline(flops=flops, hbm_bytes=nbytes, collective=stats,
                    chips=chips, model_flops=model_flops)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D forward."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.mode == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    d = shape.global_batch * 1
    return 2.0 * n * d
