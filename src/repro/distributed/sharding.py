"""Sharding rules: map every parameter / batch / cache leaf to a
PartitionSpec over the production mesh.

Conventions (Megatron-style TP over ``model``, DP over ``pod``+``data``):

* attention: Wq/Wk/Wv column-parallel (fused head dim), Wo row-parallel;
* MLP: up/gate column-parallel, down row-parallel;
* MoE: experts sharded over ``model`` (expert parallelism; the shard_map
  dispatch in ``repro.models.moe`` gathers locally and psums);
* SSM: the input projection is split per consumer slice — in_z / in_xbc
  / in_dt each column-parallel on its own output dim, so z, the fused
  xBC conv block and dt land already aligned with their consumers (the
  former fused in_proj forced GSPMD to reshard every slice);
  out_proj row-parallel;
* embeddings / unembedding vocab-sharded (vocabs padded to %512);
* KV caches: kv-head-sharded when num_kv_heads % model_size == 0, else
  head-dim-sharded (head_dim of every assigned arch divides 16);
* optimizer moments: parameter specs, plus ZeRO-1 (shard the first
  un-sharded divisible dim over ``data``).

Sharded serving (the TP-sharded mixed ragged step)
--------------------------------------------------
The serving engine's ONE jitted mixed step (``serving.runner._mixed_impl``)
runs tensor-parallel over ``EngineConfig.mesh`` using the specs below.
The host-side scheduler, block manager and adapter registry stay
single-process; only the step's inputs/outputs are sharded arrays.
Per-input layout contract:

* **params** — :func:`param_specs_tree` with ``mesh=`` (Megatron TP as
  above; any dim that does not divide its mesh axes falls back to
  replicated, so every config lowers on every mesh);
* **paged K/V pools** ``(La, NB, bs, KV, hd)`` — split on the KV-head
  dim when both head counts divide the model axis, else on ``hd``
  (:func:`mixed_step_shardings`; the paged analogue of
  :func:`kv_cache_spec` / :func:`cache_specs_tree`, which keep the
  dense-cache ``(repeats, count, B, S, KV, hd)`` layout);
* **SSM live/snapshot state pools** ``(Ls, slots, nh, N, P)`` /
  ``(Ls, slots, W-1, ch)`` — sharded on ``nh`` / channel when divisible;
* **adapter slot stacks** (``serving.adapter_pool``) — leaves
  ``(S+1, d, r)`` for A are REPLICATED (rank ≪ d, the A matmul is
  cheap and its output feeds every shard), leaves ``(S+1, r, out)``
  for B are column-parallel on ``out`` (:func:`adapter_slot_specs`), so
  the ragged grouped-LoRA delta is computed locally per shard and added
  to the already column-parallel base projection with NO extra
  collective;
* **per-token scheduler metadata** (token ids, positions, adapter
  indices, block tables, write indices, ...) — replicated (``P()``);
* **sampled-token outputs + the per-run-slot token buffer** — both
  replicated (:attr:`StepShardings.tok_buf`): the in-step argmax over
  the vocab-gathered logits is the single cross-shard reduction point
  on the delta path (row-parallel wo/w_down/out_proj psums are the only
  other collectives, exactly as in training TP), and every shard must
  hold the full token buffer so the next step's ``from_buf`` gathers
  stay collective-free;
* **boundary-state outputs** — boundary SSM states keep the state-pool
  layout.

``jax.jit`` + GSPMD partitions the step from these input layouts; the
``StepShardings`` carried statically in the runner spec pins the output
layouts with ``with_sharding_constraint`` so pools never reshard between
steps (zero post-warmup recompiles).  ``tests/test_sharded_step.py``
asserts token-for-token equivalence with the single-device path on an
8-way host mesh across attention, SSM and encoder-decoder families.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Mapping, Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

Tree = Any
# mesh-shaped things: a real Mesh, or a {axis: size} mapping (property
# tests probe mesh shapes larger than the host's device count)
MeshLike = Union[Mesh, Mapping[str, int]]


def _axis_sizes(mesh: MeshLike) -> Mapping[str, int]:
    return mesh.shape if isinstance(mesh, Mesh) else mesh


def _shards_of(axes, sizes: Mapping[str, int]) -> int:
    names = axes if isinstance(axes, tuple) else (axes,)
    n = 1
    for a in names:
        n *= int(sizes[a])
    return n


def fit_spec(spec: P, shape: Tuple[int, ...], mesh: MeshLike) -> P:
    """Drop (to replicated) every spec dim whose axis product does not
    divide the corresponding array dim — the guarantee that makes every
    spec tree valid on every mesh (property-tested)."""
    sizes = _axis_sizes(mesh)
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = [ax if ax is not None and d % _shards_of(ax, sizes) == 0
           else None
           for d, ax in zip(shape, dims)]
    return P(*out)


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` across jax versions: the new top-level API takes
    ``check_vma``; 0.4.x only has ``jax.experimental.shard_map`` whose
    equivalent knob is ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kw)
    from jax.experimental.shard_map import shard_map as sm_old
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kw)


def _leaf_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ModelConfig, model: str, n_lead: int) -> P:
    """Spec for one parameter leaf.  ``n_lead`` = stacking dims (layer
    repeats/count, adapter index) prepended as None."""
    name = path[-1]
    lead = (None,) * n_lead
    core = len(shape) - n_lead

    def spec(*dims):
        assert len(dims) == core, (path, shape, dims)
        return P(*(lead + dims))

    if name in ("tok",):
        return P(model, None)
    if name in ("unembed",):
        return P(None, model)
    if name in ("wq", "wk", "wv", "w_up", "w_gate",
                "in_z", "in_xbc", "in_dt"):
        if core == 3:                       # MoE expert stacks (E, d, ff)
            return spec(model, None, None)
        return spec(None, model)
    if name in ("wo", "w_down", "out_proj"):
        if core == 3:                       # MoE (E, ff, d)
            return spec(model, None, None)
        return spec(model, None)
    if name in ("aq", "ak", "av", "a"):     # adapter A: (d, r)
        return spec(None, None)
    if name in ("bq", "bk", "bv"):          # adapter B: (r, out)
        return spec(None, model)
    if name == "b":                         # ssm adapter B
        return spec(None, model)
    # everything else (norms, router, conv, A_log, dt_bias, D, biases)
    return P(*((None,) * len(shape)))


def _n_lead_dims(path) -> int:
    """blocks/segN leaves carry (repeats, count) stacking; encoder blocks
    carry (L, 1); adapter stacks additionally an adapter dim."""
    keys = [str(getattr(p, "key", "")) for p in path]
    n = 0
    if any(k.startswith("seg") for k in keys) or "blocks" in keys:
        n = 2
    return n


def param_specs_tree(cfg: ModelConfig, params_shape: Tree,
                     model_axis: str = "model",
                     extra_lead: int = 0,
                     mesh: Optional[MeshLike] = None) -> Tree:
    """PartitionSpec tree matching ``params_shape`` (a ShapeDtypeStruct
    tree from ``jax.eval_shape``).  ``extra_lead`` adds leading dims
    (e.g. the stacked-adapter axis).  With ``mesh`` given, every spec is
    validated against the mesh's axis sizes: a dim that does not divide
    falls back to replicated (``fit_spec``), so the returned tree is
    always directly lowerable on that mesh."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        n_lead = _n_lead_dims(path) + extra_lead
        names = tuple(str(getattr(p, "key", p)) for p in path)
        s = _leaf_spec(names, leaf.shape, cfg, model_axis,
                       min(n_lead, len(leaf.shape)))
        if mesh is not None:
            s = fit_spec(s, leaf.shape, mesh)
        specs.append(s)
    return tdef.unflatten(specs)


def fsdp_param_specs_tree(cfg: ModelConfig, params_shape: Tree,
                          mesh: Mesh, data_axis: str = "data") -> Tree:
    """Context-parallel / FSDP weight layout (§Perf iteration 3): every
    matrix shards its first core dim over ``data`` (gathered per use);
    nothing lives on ``model`` — that axis carries the SEQUENCE shard of
    the activations instead.  Memory per chip matches the TP layout
    (params / 16)."""
    ds = mesh.shape[data_axis]
    flat, tdef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        n_lead = min(_n_lead_dims(path), len(leaf.shape))
        core = leaf.shape[n_lead:]
        dims = [None] * len(leaf.shape)
        if len(core) >= 2 and core[0] % ds == 0 and core[0] >= ds:
            dims[n_lead] = data_axis
        specs.append(P(*dims))
    return tdef.unflatten(specs)


def adapter_specs_tree(cfg: ModelConfig, ad_shape: Tree,
                       model_axis: str = "model",
                       mesh: Optional[MeshLike] = None) -> Tree:
    """Adapter stacks: leaves are (repeats, count, n_adapters, ...)."""
    return param_specs_tree(cfg, ad_shape, model_axis, extra_lead=1,
                            mesh=mesh)


def adapter_slot_specs(cfg: ModelConfig, layer_shape: Tree,
                       mesh: Optional[MeshLike] = None,
                       model_axis: str = "model") -> Tree:
    """Specs for ONE layer's device-resident adapter slot stack (the
    ``AdapterPool.layers`` entries): leaves ``(S+1, d, r)`` for A —
    replicated (rank ≪ d) — and ``(S+1, r, out)`` for B — column-
    parallel on ``out``, matching the base projection it adds into, so
    the grouped-LoRA delta needs no collective of its own."""
    return param_specs_tree(cfg, layer_shape, model_axis, extra_lead=1,
                            mesh=mesh)


def batch_specs(batch_axes: Tuple[str, ...]) -> Dict[str, P]:
    return {
        "tokens": P(batch_axes, None),
        "labels": P(batch_axes, None),
        "mask": P(batch_axes, None),
        "extra_embeds": P(batch_axes, None, None),
    }


def kv_cache_spec(cfg: ModelConfig, batch_axes, model_axis: str,
                  batch_shardable: bool = True,
                  mesh: Optional[MeshLike] = None) -> P:
    """(repeats, count, B, S, KV, hd) — heads only when BOTH q and kv
    head counts divide the model axis, else head_dim: the one rule every
    K/V layout helper (this, :func:`cache_specs_tree`,
    :func:`mixed_step_shardings`) shares.  Without a mesh, assumes the
    production 16-way model axis."""
    b = batch_axes if batch_shardable else None
    ms = 16 if mesh is None else _axis_sizes(mesh)[model_axis]
    if _kv_on_heads(cfg, ms):
        return P(None, None, b, None, model_axis, None)
    return P(None, None, b, None, None,
             model_axis if cfg.head_dim % ms == 0 else None)


def _kv_on_heads(cfg: ModelConfig, ms: int) -> bool:
    """THE heads-vs-head_dim rule every K/V layout helper shares
    (:func:`kv_cache_spec`, :func:`cache_specs_tree`,
    :func:`mixed_step_shardings`): shard the KV-head dim only when BOTH
    q and kv head counts divide the model axis (GQA attention stays
    fully head-parallel), else fall back to the head_dim dim."""
    return cfg.num_kv_heads % ms == 0 and cfg.num_heads % ms == 0


def cache_specs_tree(cfg: ModelConfig, caches_shape: Tree, mesh: MeshLike,
                     batch_axes: Tuple[str, ...],
                     model_axis: str = "model",
                     batch_shardable: bool = True) -> Tree:
    """Specs for decode/prefill cache trees."""
    ms = _axis_sizes(mesh)[model_axis]
    b = batch_axes if batch_shardable else None

    def leaf(path, s):
        name = str(getattr(path[-1], "key", path[-1]))
        shape = s.shape
        if name in ("k", "v", "xk", "xv"):
            # (repeats, count, B, S, KV, hd) — layout must match
            # models.model._attn_head_specs (the shared _kv_on_heads
            # rule); dense dry-run caches ASSERT on a non-divisible
            # head_dim rather than silently replicating a hot tensor
            if _kv_on_heads(cfg, ms):
                return P(None, None, b, None, model_axis, None)
            assert cfg.head_dim % ms == 0, (cfg.name, cfg.head_dim, ms)
            return P(None, None, b, None, None, model_axis)
        if name in ("ks", "vs"):
            # int8-cache scales: (repeats, count, B, S, KV)
            if _kv_on_heads(cfg, ms):
                return P(None, None, b, None, model_axis)
            return P(None, None, b, None, None)
        if name == "ssm":
            # (repeats, count, B, nh, N, P)
            nh = shape[3]
            return P(None, None, b,
                     model_axis if nh % ms == 0 else None, None, None)
        if name == "conv":
            # (repeats, count, B, W-1, ch)
            ch = shape[4]
            return P(None, None, b, None,
                     model_axis if ch % ms == 0 else None)
        return P(*((None,) * len(shape)))

    flat, tdef = jax.tree_util.tree_flatten_with_path(caches_shape)
    return tdef.unflatten([leaf(p, s) for p, s in flat])


# ---------------------------------------------------------------------------
# Sharded serving: layout of the mixed ragged step's device state
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class StepShardings:
    """Static (hashable) sharding context for the serving runner's jitted
    mixed step — carried inside ``RunnerSpec`` so output layouts are
    pinned with ``with_sharding_constraint`` and pools never reshard
    between steps.  ``None`` state specs mean the arch has no SSM pools.
    """
    mesh: Mesh
    kv_pool: P                       # (La, NB, bs, KV, hd)
    ssm_pool: Optional[P] = None     # (Ls, slots, nh, N, P)
    conv_pool: Optional[P] = None    # (Ls, slots, W-1, ch)
    # (T, H, hd) per-token attention output — follows the K/V layout
    # (heads when both head counts divide, else head_dim); annotating it
    # keeps the ragged-attention PV einsum shard-local instead of letting
    # the partitioner rematerialize the gathered V rows
    attn_out: Optional[P] = None
    # (MR,) per-run-slot last-sampled-token buffer AND the (Rb,) sampled
    # ids — replicated: the step's argmax all-gathers once at the
    # unembed, then every shard keeps the full int32 buffer so the next
    # step's from_buf token gathers stay collective-free
    tok_buf: P = P()
    # (Tb,) per-token metadata rows / (Tb, d) input embeds.  P() (the
    # TP-only layout) replicates the packed token axis on every device;
    # data-parallel token sharding sets these to P(data) / P(data, None)
    # so each data shard holds only its slice of the step's tokens and
    # ``max_batched_tokens`` scales with the data axis.  Per-REQUEST
    # arrays (block tables, out_rows, run_slots) and the sampled ids
    # stay replicated — retirement and the next step's from_buf gathers
    # still see every request on every shard.
    tok_meta: P = P()
    tok_embeds: P = P()
    replicated: P = P()

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, spec: Optional[P]):
        if x is None or spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.named(spec))


def mixed_step_shardings(cfg: ModelConfig, mesh: MeshLike,
                         model_axis: str = "model",
                         data_axis: Optional[str] = None) -> StepShardings:
    """Layouts for the paged serving pools over ``mesh``.

    The K/V pool follows the same head-vs-head_dim rule as
    :func:`cache_specs_tree` (heads only when BOTH q and kv head counts
    divide the model axis); SSM pools shard their head / channel dims
    when divisible, else replicate.  (Property tests pass a plain
    ``{axis: size}`` mapping; the serving runner passes the real mesh.)

    ``data_axis`` (when present in the mesh with size > 1) additionally
    shards the packed TOKEN axis of the mixed step over that axis:
    per-token metadata rows and input embeds split so each data shard
    computes only its slice of the step's tokens (the runner pads the
    token bucket to a multiple of the axis size).  Per-request arrays,
    the token buffer and the sampled ids stay replicated.
    """
    sizes = _axis_sizes(mesh)
    ms = sizes[model_axis]
    tok_ax = data_axis if data_axis is not None \
        and sizes.get(data_axis, 1) > 1 else None
    if _kv_on_heads(cfg, ms):
        kv = P(None, None, None, model_axis, None)
        attn_out = P(tok_ax, model_axis, None)
    else:
        hd_ax = model_axis if cfg.head_dim % ms == 0 else None
        kv = P(None, None, None, None, hd_ax)
        attn_out = P(tok_ax, None, hd_ax)
    ssm_pool = conv_pool = None
    if cfg.num_ssm_layers() > 0:
        from repro.models.ssm import ssm_dims
        _, nh, ch = ssm_dims(cfg)
        ssm_pool = P(None, None, model_axis if nh % ms == 0 else None,
                     None, None)
        conv_pool = P(None, None, None,
                      model_axis if ch % ms == 0 else None)
    return StepShardings(mesh=mesh, kv_pool=kv, ssm_pool=ssm_pool,
                         conv_pool=conv_pool, attn_out=attn_out,
                         tok_meta=P(tok_ax), tok_embeds=P(tok_ax, None))


def zero1_specs(param_spec_tree: Tree, params_shape: Tree, mesh: Mesh,
                data_axis: str = "data") -> Tree:
    """ZeRO-1: shard optimizer moments over ``data`` on the first dim
    that is unsharded and divisible (beyond-paper memory optimization)."""
    ds = mesh.shape[data_axis]

    def leaf(spec: P, s) -> P:
        dims = list(spec) + [None] * (len(s.shape) - len(spec))
        for i, (d, cur) in enumerate(zip(s.shape, dims)):
            if cur is None and d % ds == 0 and d >= ds:
                dims[i] = data_axis
                return P(*dims)
        return spec

    return jax.tree.map(leaf, param_spec_tree, params_shape,
                        is_leaf=lambda x: isinstance(x, P))


def to_named(tree_specs: Tree, mesh: Mesh) -> Tree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
