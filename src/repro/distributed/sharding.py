"""Sharding rules: map every parameter / batch / cache leaf to a
PartitionSpec over the production mesh.

Conventions (Megatron-style TP over ``model``, DP over ``pod``+``data``):

* attention: Wq/Wk/Wv column-parallel (fused head dim), Wo row-parallel;
* MLP: up/gate column-parallel, down row-parallel;
* MoE: experts sharded over ``model`` (expert parallelism; the shard_map
  dispatch in ``repro.models.moe`` gathers locally and psums);
* SSM: in_proj column-parallel over the fused [z,x,B,C,dt] dim (XLA
  reshards the component slices; splitting the fused matrix is a §Perf
  candidate), out_proj row-parallel;
* embeddings / unembedding vocab-sharded (vocabs padded to %512);
* KV caches: kv-head-sharded when num_kv_heads % model_size == 0, else
  head-dim-sharded (head_dim of every assigned arch divides 16);
* optimizer moments: parameter specs, plus ZeRO-1 (shard the first
  un-sharded divisible dim over ``data``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

Tree = Any


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` across jax versions: the new top-level API takes
    ``check_vma``; 0.4.x only has ``jax.experimental.shard_map`` whose
    equivalent knob is ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kw)
    from jax.experimental.shard_map import shard_map as sm_old
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kw)


def _leaf_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ModelConfig, model: str, n_lead: int) -> P:
    """Spec for one parameter leaf.  ``n_lead`` = stacking dims (layer
    repeats/count, adapter index) prepended as None."""
    name = path[-1]
    lead = (None,) * n_lead
    core = len(shape) - n_lead

    def spec(*dims):
        assert len(dims) == core, (path, shape, dims)
        return P(*(lead + dims))

    if name in ("tok",):
        return P(model, None)
    if name in ("unembed",):
        return P(None, model)
    if name in ("wq", "wk", "wv", "w_up", "w_gate", "in_proj"):
        if core == 3:                       # MoE expert stacks (E, d, ff)
            return spec(model, None, None)
        return spec(None, model)
    if name in ("wo", "w_down", "out_proj"):
        if core == 3:                       # MoE (E, ff, d)
            return spec(model, None, None)
        return spec(model, None)
    if name in ("aq", "ak", "av", "a"):     # adapter A: (d, r)
        return spec(None, None)
    if name in ("bq", "bk", "bv"):          # adapter B: (r, out)
        return spec(None, model)
    if name == "b":                         # ssm adapter B
        return spec(None, model)
    # everything else (norms, router, conv, A_log, dt_bias, D, biases)
    return P(*((None,) * len(shape)))


def _n_lead_dims(path) -> int:
    """blocks/segN leaves carry (repeats, count) stacking; encoder blocks
    carry (L, 1); adapter stacks additionally an adapter dim."""
    keys = [str(getattr(p, "key", "")) for p in path]
    n = 0
    if any(k.startswith("seg") for k in keys) or "blocks" in keys:
        n = 2
    return n


def param_specs_tree(cfg: ModelConfig, params_shape: Tree,
                     model_axis: str = "model",
                     extra_lead: int = 0) -> Tree:
    """PartitionSpec tree matching ``params_shape`` (a ShapeDtypeStruct
    tree from ``jax.eval_shape``).  ``extra_lead`` adds leading dims
    (e.g. the stacked-adapter axis)."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        n_lead = _n_lead_dims(path) + extra_lead
        names = tuple(str(getattr(p, "key", p)) for p in path)
        specs.append(_leaf_spec(names, leaf.shape, cfg, model_axis,
                                min(n_lead, len(leaf.shape))))
    return tdef.unflatten(specs)


def fsdp_param_specs_tree(cfg: ModelConfig, params_shape: Tree,
                          mesh: Mesh, data_axis: str = "data") -> Tree:
    """Context-parallel / FSDP weight layout (§Perf iteration 3): every
    matrix shards its first core dim over ``data`` (gathered per use);
    nothing lives on ``model`` — that axis carries the SEQUENCE shard of
    the activations instead.  Memory per chip matches the TP layout
    (params / 16)."""
    ds = mesh.shape[data_axis]
    flat, tdef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        n_lead = min(_n_lead_dims(path), len(leaf.shape))
        core = leaf.shape[n_lead:]
        dims = [None] * len(leaf.shape)
        if len(core) >= 2 and core[0] % ds == 0 and core[0] >= ds:
            dims[n_lead] = data_axis
        specs.append(P(*dims))
    return tdef.unflatten(specs)


def adapter_specs_tree(cfg: ModelConfig, ad_shape: Tree,
                       model_axis: str = "model") -> Tree:
    """Adapter stacks: leaves are (repeats, count, n_adapters, ...)."""
    return param_specs_tree(cfg, ad_shape, model_axis, extra_lead=1)


def batch_specs(batch_axes: Tuple[str, ...]) -> Dict[str, P]:
    return {
        "tokens": P(batch_axes, None),
        "labels": P(batch_axes, None),
        "mask": P(batch_axes, None),
        "extra_embeds": P(batch_axes, None, None),
    }


def kv_cache_spec(cfg: ModelConfig, batch_axes, model_axis: str,
                  batch_shardable: bool = True) -> P:
    """(repeats, count, B, S, KV, hd)."""
    b = batch_axes if batch_shardable else None
    return P(None, None, b, None, model_axis, None) \
        if _kv_on_heads(cfg, model_axis) else \
        P(None, None, b, None, None, model_axis)


def _kv_on_heads(cfg: ModelConfig, model_axis: str) -> bool:
    # resolved at lowering time against the mesh in cache_specs_tree
    return cfg.num_kv_heads % 16 == 0


def cache_specs_tree(cfg: ModelConfig, caches_shape: Tree, mesh: Mesh,
                     batch_axes: Tuple[str, ...],
                     model_axis: str = "model",
                     batch_shardable: bool = True) -> Tree:
    """Specs for decode/prefill cache trees."""
    ms = mesh.shape[model_axis]
    b = batch_axes if batch_shardable else None

    def leaf(path, s):
        name = str(getattr(path[-1], "key", path[-1]))
        shape = s.shape
        if name in ("k", "v", "xk", "xv"):
            # (repeats, count, B, S, KV, hd) — layout must match
            # models.model._attn_head_specs: heads only when BOTH q and
            # kv head counts divide the model axis, else head_dim
            if cfg.num_kv_heads % ms == 0 and cfg.num_heads % ms == 0:
                return P(None, None, b, None, model_axis, None)
            assert cfg.head_dim % ms == 0, (cfg.name, cfg.head_dim, ms)
            return P(None, None, b, None, None, model_axis)
        if name in ("ks", "vs"):
            # int8-cache scales: (repeats, count, B, S, KV)
            if cfg.num_kv_heads % ms == 0 and cfg.num_heads % ms == 0:
                return P(None, None, b, None, model_axis)
            return P(None, None, b, None, None)
        if name == "ssm":
            # (repeats, count, B, nh, N, P)
            nh = shape[3]
            return P(None, None, b,
                     model_axis if nh % ms == 0 else None, None, None)
        if name == "conv":
            # (repeats, count, B, W-1, ch)
            ch = shape[4]
            return P(None, None, b, None,
                     model_axis if ch % ms == 0 else None)
        return P(*((None,) * len(shape)))

    flat, tdef = jax.tree_util.tree_flatten_with_path(caches_shape)
    return tdef.unflatten([leaf(p, s) for p, s in flat])


def zero1_specs(param_spec_tree: Tree, params_shape: Tree, mesh: Mesh,
                data_axis: str = "data") -> Tree:
    """ZeRO-1: shard optimizer moments over ``data`` on the first dim
    that is unsharded and divisible (beyond-paper memory optimization)."""
    ds = mesh.shape[data_axis]

    def leaf(spec: P, s) -> P:
        dims = list(spec) + [None] * (len(s.shape) - len(spec))
        for i, (d, cur) in enumerate(zip(s.shape, dims)):
            if cur is None and d % ds == 0 and d >= ds:
                dims[i] = data_axis
                return P(*dims)
        return spec

    return jax.tree.map(leaf, param_spec_tree, params_shape,
                        is_leaf=lambda x: isinstance(x, P))


def to_named(tree_specs: Tree, mesh: Mesh) -> Tree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
